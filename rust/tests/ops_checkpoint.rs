//! Checkpoint/resume: the ops subsystem's bit-identity contract.
//!
//! Property side (driver: `fedpaq::util::prop` — proptest is unavailable
//! offline): random checkpoints round-trip the binary format exactly,
//! and truncated or corrupted bytes are rejected without panics or
//! runaway allocations.
//!
//! Integration side: a run killed at commit `K` (via
//! `RunControl::stop_after`, the signal-free kill) and resumed from its
//! checkpoint must produce a [`RunResult`] **bit-identical** to the
//! uninterrupted run — losses, virtual times, traffic and telemetry —
//! on both the synchronous in-process transport (with stateful
//! error-feedback codec residuals crossing the checkpoint) and the
//! buffered-async simulator (with non-quiescent in-flight jobs crossing
//! it).

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::{PlannerState, RunResult, ServerBuilder, StalenessRule};
use fedpaq::metrics::CurvePoint;
use fedpaq::model::{ModelKind, RustEngine};
use fedpaq::ops::{Checkpoint, JobState, RunControl, TransportState};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::{CodecSpec, Encoded};
use fedpaq::util::prop::check;
use fedpaq::util::rng::Rng;
use std::path::PathBuf;

// ---------------------------------------------------------------------
// Property tests over the binary format.
// ---------------------------------------------------------------------

fn rand_enc(rng: &mut Rng) -> Encoded {
    let codec = CodecSpec::qsgd(rng.gen_range(1, 5) as u32).build().unwrap();
    let n = rng.gen_range(1, 24);
    let x: Vec<f32> = (0..n).map(|_| rng.gen_f32() - 0.5).collect();
    codec.encode(&x, rng)
}

fn rand_checkpoint(rng: &mut Rng) -> Checkpoint {
    let transport = if rng.gen_bool(0.5) {
        Some(TransportState::Async {
            planner: PlannerState {
                seed: rng.next_u64(),
                n_nodes: rng.gen_range(1, 30),
                buffer_size: rng.gen_range(1, 8),
                max_staleness: rng.gen_range(0, 8),
                version: rng.gen_range(0, 100),
                wave_len: rng.gen_range(0, 8),
                awaiting_wave: rng.gen_bool(0.5),
                in_flight: (0..rng.gen_range(0, 5))
                    .map(|i| (rng.gen_range(0, 30), rng.gen_range(0, 100), i))
                    .collect(),
                buffer: (0..rng.gen_range(0, 4))
                    .map(|i| {
                        (rng.gen_range(0, 30), rng.gen_range(0, 100), i, rand_enc(rng))
                    })
                    .collect(),
                dropped_total: rng.next_u64() >> 40,
                dropped_since_commit: rng.next_u64() >> 50,
                redispatches: rng.next_u64() >> 40,
            },
            now: rng.gen_f32() as f64 * 1e3,
            jobs: (0..rng.gen_range(0, 4))
                .map(|i| JobState {
                    node: rng.gen_range(0, 30),
                    version: rng.gen_range(0, 100),
                    slot: i,
                    finish: rng.gen_f32() as f64 * 1e3,
                    enc: rand_enc(rng),
                })
                .collect(),
        })
    } else {
        None
    };
    Checkpoint {
        config_hash: rng.next_u64(),
        seed: rng.next_u64(),
        next_round: rng.gen_range(0, 1000),
        total_bits: rng.next_u64() >> 20,
        total_bits_down: rng.next_u64() >> 20,
        total_bits_edge_to_root: rng.next_u64() >> 20,
        clock_now: rng.gen_f32() as f64 * 1e4,
        params: (0..rng.gen_range(1, 40)).map(|_| rng.gen_f32() - 0.5).collect(),
        curve_label: format!("run-{}", rng.gen_range(0, 1000)),
        curve: (0..rng.gen_range(0, 6))
            .map(|k| CurvePoint {
                round: k,
                iterations: k * 5,
                time: k as f64 * 1.5,
                bits_up: rng.next_u64() >> 30,
                bits_down: rng.next_u64() >> 30,
                bits_edge_to_root: rng.next_u64() >> 30,
                loss: rng.gen_f32() as f64,
            })
            .collect(),
        stats: Vec::new(),
        codec_state: (0..rng.gen_range(0, 5))
            .map(|i| {
                (i as u64, (0..rng.gen_range(1, 8)).map(|_| rng.gen_f32()).collect())
            })
            .collect(),
        down_reference: (0..rng.gen_range(0, 20)).map(|_| rng.gen_f32() - 0.5).collect(),
        down_link_bits: (0..rng.gen_range(0, 6)).map(|_| rng.next_u64() >> 40).collect(),
        down_last: (0..rng.gen_range(0, 8)).map(|_| rng.next_u64() % 100).collect(),
        down_codec_state: (0..rng.gen_range(0, 3))
            .map(|i| {
                (i as u64, (0..rng.gen_range(1, 8)).map(|_| rng.gen_f32()).collect())
            })
            .collect(),
        rng_states: (0..rng.gen_range(0, 3))
            .map(|i| (i as u64, [rng.next_u64(); 4]))
            .collect(),
        transport,
    }
}

#[test]
fn prop_random_checkpoints_roundtrip_bit_exactly() {
    check(60, 0x0b5_c4e0, |rng| {
        let ck = rand_checkpoint(rng);
        let bytes = ck.encode();
        let back = Checkpoint::decode(&bytes).unwrap();
        // Byte-level re-encode equality covers every field, including
        // the nested planner snapshot and in-flight job payloads.
        assert_eq!(bytes, back.encode());
        assert_eq!(ck.id(), back.id());
    });
}

#[test]
fn prop_truncation_and_corruption_are_rejected_cleanly() {
    check(40, 0x0b5_c4e1, |rng| {
        let bytes = rand_checkpoint(rng).encode();
        // Any strict prefix must fail with an error, never a panic.
        for _ in 0..8 {
            let cut = rng.gen_range(0, bytes.len());
            assert!(Checkpoint::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // A random byte flip must never panic or hang; decoding may
        // still succeed when the flip lands in float payload bytes.
        let mut corrupt = bytes.clone();
        let at = rng.gen_range(0, corrupt.len());
        corrupt[at] ^= 1 << rng.gen_range(0, 8);
        let _ = Checkpoint::decode(&corrupt);
    });
}

// ---------------------------------------------------------------------
// Kill/resume bit-identity on the in-process transports.
// ---------------------------------------------------------------------

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        name: "ops-ck-it".into(),
        model: "logreg".into(),
        dataset: fedpaq::data::DatasetKind::Mnist08,
        n_nodes: 12,
        per_node: 40,
        r: 6,
        tau: 3,
        t_total: 36, // 12 commits
        codec: CodecSpec::qsgd(2),
        lr: LrSchedule::Const { eta: 0.4 },
        ratio: 100.0,
        seed: 29,
        eval_every: 1,
        engine: EngineKind::Rust,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: false,
        buffer_size: 0,
        max_staleness: 8,
        staleness_rule: StalenessRule::Uniform,
        agg_shards: 1,
        down_codec: None,
        straggler: Default::default(),
        dataset_cap: 0,
    }
}

fn engine() -> RustEngine {
    RustEngine::new(ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 480).unwrap()
}

fn run_ctrl(cfg: &ExperimentConfig, ctrl: RunControl) -> RunResult {
    let mut eng = engine();
    ServerBuilder::new(cfg.clone())
        .engine(&mut eng)
        .control(ctrl)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

/// Exact equality of everything a RunResult records (modulo meta
/// provenance, asserted separately): losses, virtual times, bits,
/// per-round telemetry and the final model.
fn assert_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.params, b.params, "final models differ");
    assert_eq!(a.total_bits, b.total_bits);
    assert_eq!(a.total_bits_down, b.total_bits_down);
    assert_eq!(a.total_bits_edge_to_root, b.total_bits_edge_to_root);
    assert_eq!(a.curve.points.len(), b.curve.points.len());
    for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
        assert_eq!(pa.round, pb.round);
        assert_eq!(pa.iterations, pb.iterations);
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "loss at k={}", pa.round);
        assert_eq!(pa.time.to_bits(), pb.time.to_bits(), "time at k={}", pa.round);
        assert_eq!(pa.bits_up, pb.bits_up);
        assert_eq!(pa.bits_down, pb.bits_down);
        assert_eq!(pa.bits_edge_to_root, pb.bits_edge_to_root);
    }
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.compute_time.to_bits(), rb.compute_time.to_bits());
        assert_eq!(ra.comm_time.to_bits(), rb.comm_time.to_bits());
        assert_eq!(ra.bits_up, rb.bits_up);
        assert_eq!(ra.bits_down, rb.bits_down);
        assert_eq!(ra.bits_edge_to_root, rb.bits_edge_to_root);
        assert_eq!(ra.dropped, rb.dropped);
        assert_eq!(ra.staleness_max, rb.staleness_max);
        assert_eq!(ra.staleness_mean.to_bits(), rb.staleness_mean.to_bits());
    }
}

fn temp_ck(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("fedpaq-ops-it-{}", std::process::id()))
        .join(name)
}

/// Shared kill/resume flow: full run vs stop-at-K + resume.
fn kill_resume_roundtrip(cfg: &ExperimentConfig, stop_after: usize, ck_name: &str) {
    let full = run_ctrl(cfg, RunControl::default());
    assert!(full.meta.resumed_from.is_none());

    let path = temp_ck(ck_name);
    let stopped = run_ctrl(
        cfg,
        RunControl {
            checkpoint_path: Some(path.clone()),
            checkpoint_every: 0, // only the forced stop_after checkpoint
            stop_after: Some(stop_after),
            ..Default::default()
        },
    );
    assert_eq!(stopped.rounds.len(), stop_after, "stop_after did not stop");

    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.next_round, stop_after);
    let ck_id = ck.id();
    let resumed = run_ctrl(cfg, RunControl { resume: Some(ck), ..Default::default() });

    assert_identical(&full, &resumed);
    assert_eq!(resumed.meta.resumed_from.as_deref(), Some(ck_id.as_str()));
    std::fs::remove_file(&path).ok();
}

#[test]
fn sync_kill_resume_is_bit_identical_with_error_feedback_residuals() {
    // Error feedback makes the codec stateful: per-node residuals must
    // cross the checkpoint exactly or the resumed uploads diverge.
    let cfg = ExperimentConfig {
        codec: CodecSpec::error_feedback(CodecSpec::qsgd(2)),
        ..base_cfg()
    };
    kill_resume_roundtrip(&cfg, 4, "sync-ef.ck");
}

#[test]
fn async_kill_resume_is_bit_identical_with_in_flight_jobs() {
    // buffer_size < r: every post-commit checkpoint carries r − b
    // in-flight jobs (with their already-computed uploads and virtual
    // completion times) plus the planner snapshot. Resume must splice
    // all of it back for the event stream to replay identically.
    let cfg = ExperimentConfig {
        async_rounds: true,
        buffer_size: 2,
        max_staleness: 8,
        ..base_cfg()
    };
    kill_resume_roundtrip(&cfg, 5, "async-buffered.ck");
}

#[test]
fn downlink_kill_resume_is_bit_identical_with_reference_state() {
    // Bidirectional compression: the checkpoint must carry the server's
    // downlink reference model, per-version link bits, per-node chain
    // positions and the (stateful, error-feedback) downlink codec's
    // residuals — the resumed run re-encodes link K+1 against the exact
    // reference the killed run held, so every later broadcast, upload
    // and bit count matches the uninterrupted run bit for bit.
    let cfg = ExperimentConfig {
        async_rounds: true,
        buffer_size: 2,
        max_staleness: 8,
        down_codec: Some(CodecSpec::error_feedback(CodecSpec::qsgd(4))),
        ..base_cfg()
    };
    kill_resume_roundtrip(&cfg, 5, "async-downlink.ck");
}

#[test]
fn scale_kill_resume_is_bit_identical_and_exports_jobs_canonically() {
    // 10^5-client cohort in O(r + dataset) memory: shards wrap a
    // 2048-sample capped dataset, sampling is Floyd O(r), and the
    // in-flight set is r = 32 jobs however large the cohort. The same
    // kill/resume flow as the small configs must hold — and the
    // checkpoint must serialize its in-flight jobs in the canonical
    // event-queue order (sorted by `(finish, version, slot, node)`),
    // independent of the heap's internal layout, or checkpoint bytes
    // would depend on insertion history.
    let cfg = ExperimentConfig {
        name: "ops-ck-scale".into(),
        n_nodes: 100_000,
        per_node: 32,
        r: 32,
        tau: 1,
        t_total: 10, // 10 commits
        async_rounds: true,
        buffer_size: 8,
        max_staleness: 8,
        straggler: fedpaq::simtime::StragglerDist::Pareto { alpha: 1.5 },
        dataset_cap: 2048,
        ..base_cfg()
    };

    let full = run_ctrl(&cfg, RunControl::default());
    let path = temp_ck("scale.ck");
    let stopped = run_ctrl(
        &cfg,
        RunControl {
            checkpoint_path: Some(path.clone()),
            checkpoint_every: 0,
            stop_after: Some(4),
            ..Default::default()
        },
    );
    assert_eq!(stopped.rounds.len(), 4);

    let ck = Checkpoint::load(&path).unwrap();
    let Some(TransportState::Async { jobs, .. }) = &ck.transport else {
        panic!("async checkpoint must carry transport state");
    };
    // b < r ⇒ the snapshot carries in-flight stragglers, strictly
    // ordered by the event-queue key (keys are unique in-flight).
    assert_eq!(jobs.len(), cfg.r - cfg.buffer_size);
    for w in jobs.windows(2) {
        let key = |j: &JobState| (j.finish.to_bits(), j.version, j.slot, j.node);
        assert!(key(&w[0]) < key(&w[1]), "jobs not in canonical order");
    }

    let resumed = run_ctrl(&cfg, RunControl { resume: Some(ck), ..Default::default() });
    assert_identical(&full, &resumed);
    std::fs::remove_file(&path).ok();
}

#[test]
fn resume_under_a_different_config_is_rejected() {
    let cfg = base_cfg();
    let path = temp_ck("mismatch.ck");
    let _ = run_ctrl(
        &cfg,
        RunControl {
            checkpoint_path: Some(path.clone()),
            checkpoint_every: 0,
            stop_after: Some(3),
            ..Default::default()
        },
    );
    let ck = Checkpoint::load(&path).unwrap();
    let other = cfg.with_seed(99);
    let mut eng = engine();
    let err = ServerBuilder::new(other)
        .engine(&mut eng)
        .control(RunControl { resume: Some(ck), ..Default::default() })
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("different config"), "{err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn periodic_checkpoints_leave_the_newest_complete_snapshot() {
    // checkpoint_every = 3 over 12 commits: the file on disk at the end
    // is the last cadence hit (commit 12), written atomically over the
    // earlier ones.
    let cfg = base_cfg();
    let path = temp_ck("periodic.ck");
    let _ = run_ctrl(
        &cfg,
        RunControl {
            checkpoint_path: Some(path.clone()),
            checkpoint_every: 3,
            ..Default::default()
        },
    );
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.next_round, 12);
    assert_eq!(ck.seed, cfg.seed);
    std::fs::remove_file(&path).ok();
}
