//! Property tests over the downlink (server → client) compression seam
//! — the chain-reconstruction contract behind bidirectional FedPAQ:
//! for **every** codec family, a client that last held reference version
//! `v` and applies the decoded link chain `link_{v+1} … link_N` lands on
//! a reference **bit-identical** to the server's, and the per-node
//! download accounting sums exactly the link sizes the client was
//! missing.
//!
//! Like `prop_codecs.rs`, the suite honors `FEDPAQ_CODEC_FILTER` (see
//! [`fedpaq::quant::family_enabled`]) so the CI codec-conformance matrix
//! runs it once per family and a broken family names itself.
//!
//! (Driver: `fedpaq::util::prop` — proptest is unavailable offline.)

use fedpaq::coordinator::{downlink::apply_link, DownlinkEncoder};
use fedpaq::quant::{family_enabled, CodecSpec, UpdateCodec};
use fedpaq::util::prop::check;
use fedpaq::util::rng::Rng;

/// One representative spec per downlink-capable codec family, restricted
/// to the families `FEDPAQ_CODEC_FILTER` enables (all, when unset).
/// Every spec here is `rebuildable()` — the downlink contract requires
/// the client to rebuild the decoder from the config tag alone.
fn downlink_specs() -> Vec<CodecSpec> {
    let specs = vec![
        CodecSpec::Identity,
        CodecSpec::qsgd(1),
        CodecSpec::qsgd(4),
        CodecSpec::Qsgd { s: 7, coding: fedpaq::quant::Coding::Elias },
        CodecSpec::top_k(150),
        CodecSpec::RandK { k_permille: 200, seeded: true },
        CodecSpec::RandK { k_permille: 200, seeded: false },
        CodecSpec::adaptive(4),
        CodecSpec::error_feedback(CodecSpec::qsgd(3)),
        CodecSpec::error_feedback(CodecSpec::top_k(250)),
    ];
    specs.into_iter().filter(|s| family_enabled(s.family())).collect()
}

/// A deterministic pseudo-random model trajectory `x_0 … x_steps`.
fn walk(rng: &mut Rng, p: usize, steps: usize) -> Vec<Vec<f32>> {
    let mut x: Vec<f32> = (0..p).map(|_| rng.gen_f32() - 0.5).collect();
    let mut out = vec![x.clone()];
    for _ in 0..steps {
        for v in x.iter_mut() {
            *v += 0.2 * (rng.gen_f32() - 0.5);
        }
        out.push(x.clone());
    }
    out
}

#[test]
fn prop_chain_reconstruction_is_bit_exact_per_family() {
    for spec in downlink_specs() {
        check(12, 0xd0_714c, |rng| {
            let p = rng.gen_range(1, 80);
            let steps = rng.gen_range(1, 7);
            let seed = rng.next_u64();
            let versions = walk(rng, p, steps);
            let mut down =
                DownlinkEncoder::new(spec.build().unwrap(), seed, 1);
            // The client side rebuilds its decoder from the tag alone —
            // a *fresh* instance, as a TCP worker would.
            let client_codec: Box<dyn UpdateCodec> = spec.build().unwrap();
            let mut frames = Vec::new();
            for (k, x) in versions.iter().enumerate() {
                frames.push(down.begin_round(k, x).unwrap());
            }
            // From every possible held version v, the chain suffix must
            // reach the server's reference exactly.
            let mut scratch = Vec::new();
            for v in 0..versions.len() {
                let mut client = frames[v].params.clone();
                for frame in &frames[v + 1..] {
                    apply_link(
                        client_codec.as_ref(),
                        frame.link.as_ref().unwrap(),
                        &mut client,
                        &mut scratch,
                    )
                    .unwrap();
                }
                let same = client
                    .iter()
                    .zip(down.reference())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "[{spec:?}] chain from v={v} diverged");
            }
        });
    }
}

#[test]
fn prop_dispatch_accounting_sums_exactly_the_missing_links() {
    for spec in downlink_specs() {
        check(8, 0xd0_714d, |rng| {
            let p = rng.gen_range(1, 60);
            let steps = rng.gen_range(1, 6);
            let seed = rng.next_u64();
            let versions = walk(rng, p, steps);
            let mut down =
                DownlinkEncoder::new(spec.build().unwrap(), seed, 3);
            let mut bits = Vec::new();
            for (k, x) in versions.iter().enumerate() {
                let f = down.begin_round(k, x).unwrap();
                bits.push(f.link.map_or(0, |l| l.bits()));
            }
            let n = versions.len() - 1;
            // Node 0 kept up: pays each link exactly once, nothing twice.
            let mut node0 = 0;
            for (k, &b) in bits.iter().enumerate() {
                node0 += down.dispatch_bits(0, k);
                assert_eq!(
                    node0,
                    bits[..=k].iter().sum::<u64>(),
                    "[{spec:?}] cumulative bill drifted at k={k} (link={b})"
                );
            }
            // Node 1 jumps straight to the head: pays the whole chain.
            assert_eq!(
                down.dispatch_bits(1, n),
                bits[1..].iter().sum::<u64>(),
                "[{spec:?}] catch-up bill wrong"
            );
            // Re-dispatch at a version already held is free.
            assert_eq!(down.dispatch_bits(1, n), 0, "[{spec:?}]");
        });
    }
}
