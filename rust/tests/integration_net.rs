//! Distributed-mode integration: leader + workers over real localhost TCP
//! sockets (worker threads in-process, pure-rust engines), checked for
//! exact parity against the in-process simulation.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::Server;
use fedpaq::data::DatasetKind;
use fedpaq::figures::zoo_kind;
use fedpaq::model::RustEngine;
use fedpaq::net::{run_leader, run_worker_retrying};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::CodecSpec;
use std::net::TcpListener;
use std::path::Path;

fn cluster_cfg(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: "net-it".into(),
        model: "logreg".into(),
        dataset: DatasetKind::Mnist08,
        n_nodes: 12,
        per_node: 900, // 10_800 samples >= the 10_000 eval slab
        r: 6,
        tau: 2,
        t_total: 10,
        codec: CodecSpec::qsgd(2),
        lr: LrSchedule::Const { eta: 0.4 },
        ratio: 100.0,
        seed,
        eval_every: 1,
        engine: EngineKind::Rust,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: false,
        buffer_size: 0,
        max_staleness: 8,
        staleness_rule: Default::default(),
        agg_shards: 1,
        down_codec: None,
        straggler: Default::default(),
        dataset_cap: 0,
    }
}

fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

fn run_cluster(cfg: &ExperimentConfig, n_workers: usize) -> fedpaq::coordinator::RunResult {
    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // Keep re-dialing until the leader is listening.
                run_worker_retrying(
                    &addr,
                    Path::new("artifacts"),
                    Default::default(),
                    std::time::Duration::from_secs(30),
                )
                .unwrap_or_else(|e| panic!("worker failed: {e}"));
            })
        })
        .collect();
    let (kind, batch, eval_n) = zoo_kind("logreg").unwrap();
    let mut engine = RustEngine::new(kind, batch, eval_n).unwrap();
    let res = run_leader(
        cfg.clone(),
        &addr,
        n_workers,
        &mut engine,
        Path::new("artifacts"),
        &fedpaq::ops::RunControl::default(),
    )
    .unwrap();
    for w in workers {
        w.join().unwrap();
    }
    res
}

#[test]
fn distributed_matches_simulation_exactly() {
    let cfg = cluster_cfg(31);
    let dist = run_cluster(&cfg, 2);

    let (kind, batch, eval_n) = zoo_kind("logreg").unwrap();
    let mut engine = RustEngine::new(kind, batch, eval_n).unwrap();
    let sim = Server::new(cfg, &mut engine).unwrap().run().unwrap();

    // Same engine, same seeds, aggregation in node order: parameters and
    // bit counts must match exactly (bit-for-bit uploads).
    assert_eq!(dist.total_bits, sim.total_bits);
    let max_diff = dist
        .params
        .iter()
        .zip(&sim.params)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert_eq!(max_diff, 0.0, "distributed != simulated");
    // Loss trajectories match too.
    for (a, b) in dist.curve.points.iter().zip(&sim.curve.points) {
        assert!((a.loss - b.loss).abs() < 1e-12, "{} vs {}", a.loss, b.loss);
    }
}

#[test]
fn distributed_error_feedback_matches_simulation_exactly() {
    // The stateful-codec contract over real sockets: each worker owns the
    // residual memory of the nodes it serves (node → worker assignment is
    // pinned by node id), so a distributed EF(rand-k) run — 10 rounds,
    // nodes resampled and revisited across rounds — reproduces the
    // single-instance in-process simulation bit-for-bit.
    let mut cfg = cluster_cfg(33);
    cfg.codec = CodecSpec::error_feedback(CodecSpec::rand_k(200));
    let dist = run_cluster(&cfg, 2);

    let (kind, batch, eval_n) = zoo_kind("logreg").unwrap();
    let mut engine = RustEngine::new(kind, batch, eval_n).unwrap();
    let sim = Server::new(cfg, &mut engine).unwrap().run().unwrap();

    assert_eq!(dist.total_bits, sim.total_bits);
    let max_diff = dist
        .params
        .iter()
        .zip(&sim.params)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert_eq!(max_diff, 0.0, "distributed EF != simulated EF");
}

#[test]
fn worker_count_does_not_change_results() {
    let cfg = cluster_cfg(32);
    let one = run_cluster(&cfg, 1);
    let three = run_cluster(&cfg, 3);
    assert_eq!(one.total_bits, three.total_bits);
    let max_diff = one
        .params
        .iter()
        .zip(&three.params)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert_eq!(max_diff, 0.0);
}
