//! End-to-end tests for the buffered-async round protocol
//! (`coordinator::AsyncSim`): seeded determinism, the exact synchronous
//! degeneration (`buffer_size == |S_k|`, `max_staleness == 0` ⇒
//! bit-identical to the `InProcess` barrier), and the straggler-relief
//! property the mode exists for.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::{RunResult, Server, StalenessRule};
use fedpaq::model::{ModelKind, RustEngine};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::CodecSpec;

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        name: "async-it".into(),
        model: "logreg".into(),
        dataset: fedpaq::data::DatasetKind::Mnist08,
        n_nodes: 12,
        per_node: 40,
        r: 6,
        tau: 3,
        t_total: 36,
        codec: CodecSpec::qsgd(2),
        lr: LrSchedule::Const { eta: 0.4 },
        ratio: 100.0,
        seed: 17,
        eval_every: 2,
        engine: EngineKind::Rust,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: false,
        buffer_size: 0,
        max_staleness: 8,
        staleness_rule: StalenessRule::Uniform,
        agg_shards: 1,
        down_codec: None,
        straggler: Default::default(),
        dataset_cap: 0,
    }
}

fn engine() -> RustEngine {
    RustEngine::new(ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 480).unwrap()
}

fn run(cfg: ExperimentConfig) -> RunResult {
    let mut eng = engine();
    Server::new(cfg, &mut eng).unwrap().run().unwrap()
}

/// Exact curve equality: losses, virtual times, bits and round stats.
fn assert_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.params, b.params, "final models differ");
    assert_eq!(a.total_bits, b.total_bits);
    assert_eq!(a.curve.points.len(), b.curve.points.len());
    for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
        assert_eq!(pa.round, pb.round);
        assert_eq!(pa.loss, pb.loss, "loss differs at round {}", pa.round);
        assert_eq!(pa.time, pb.time, "time differs at round {}", pa.round);
        assert_eq!(pa.bits_up, pb.bits_up);
        assert_eq!(pa.bits_down, pb.bits_down);
    }
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.compute_time, rb.compute_time, "round {}", ra.round);
        assert_eq!(ra.comm_time, rb.comm_time, "round {}", ra.round);
        assert_eq!(ra.bits_up, rb.bits_up, "round {}", ra.round);
        assert_eq!(ra.bits_down, rb.bits_down, "round {}", ra.round);
    }
}

#[test]
fn async_runs_are_deterministic_in_the_seed() {
    let cfg = base_cfg().with_async(2, 8);
    let a = run(cfg.clone());
    let b = run(cfg.clone());
    assert_identical(&a, &b);
    let c = run(cfg.with_seed(18));
    assert_ne!(a.params, c.params, "different seeds must differ");
}

#[test]
fn full_buffer_zero_staleness_reproduces_sync_exactly() {
    // The ISSUE's acceptance gate: AsyncSim with buffer_size == |S_k| and
    // max_staleness == 0 is the synchronous protocol — every commit waits
    // for its whole wave, batches sort back into sampling order, and all
    // weights are 1 — so the whole RunResult must be bit-identical to the
    // InProcess barrier, virtual times included.
    let sync = run(base_cfg());
    let cfg = base_cfg();
    let r = cfg.r;
    let asynchronous = run(cfg.with_async(r, 0));
    assert_identical(&sync, &asynchronous);
}

#[test]
fn full_buffer_downlink_degeneration_holds_with_compressed_broadcasts() {
    // Bidirectional compression must not break the sync degeneration:
    // with a downlink codec both transports walk the same reference
    // chain (same [7, k] RNG coords), dispatch every wave at the commit
    // version, and bill identical per-node download bits.
    let cfg = ExperimentConfig {
        down_codec: Some(CodecSpec::qsgd(4)),
        ..base_cfg()
    };
    let sync = run(cfg.clone());
    assert!(sync.total_bits_down > 0, "downlink bits unbilled");
    let r = cfg.r;
    let asynchronous = run(cfg.with_async(r, 0));
    assert_eq!(sync.total_bits_down, asynchronous.total_bits_down);
    assert_identical(&sync, &asynchronous);
}

#[test]
fn full_buffer_equivalence_holds_under_every_staleness_rule() {
    // All rules weight staleness-0 uploads at exactly 1.0, so the
    // degeneration is rule-independent.
    let sync = run(base_cfg());
    for rule in [StalenessRule::inverse(), StalenessRule::Polynomial { a: 0.5 }] {
        let cfg = base_cfg();
        let r = cfg.r;
        let a = run(cfg.with_async(r, 0).with_staleness_rule(rule));
        assert_identical(&sync, &a);
    }
}

#[test]
fn small_buffers_commit_in_less_virtual_time_than_the_barrier() {
    // The point of the mode: a commit waits for the buffer to fill, not
    // for the slowest of r sampled nodes, so the same number of commits
    // costs less virtual time end-to-end.
    let sync = run(base_cfg());
    let buffered = run(base_cfg().with_async(2, 8));
    assert_eq!(sync.rounds.len(), buffered.rounds.len());
    let t = |r: &RunResult| r.curve.points.last().unwrap().time;
    assert!(
        t(&buffered) < t(&sync),
        "buffered-async should be faster: {} vs {}",
        t(&buffered),
        t(&sync)
    );
    // And it still trains.
    let first = buffered.curve.points.first().unwrap().loss;
    let last = buffered.curve.points.last().unwrap().loss;
    assert!(last < first * 0.9, "async loss did not decrease: {first} -> {last}");
}

#[test]
fn staleness_damping_trains_with_stale_uploads_in_the_mix() {
    let cfg = base_cfg()
        .with_async(2, 12)
        .with_staleness_rule(StalenessRule::inverse());
    let res = run(cfg);
    let first = res.curve.points.first().unwrap().loss;
    let last = res.curve.points.last().unwrap().loss;
    assert!(last < first * 0.9, "damped async did not train: {first} -> {last}");
    // Virtual time stays strictly monotone across commits.
    let mut t = -1.0;
    for p in &res.curve.points {
        assert!(p.time > t || (p.round == 0 && p.time == 0.0), "time not monotone");
        t = p.time;
    }
}

#[test]
fn sharded_async_aggregation_is_bit_identical_to_single_shard() {
    // The sharded-aggregation contract on the async path, where staleness
    // weights ≠ 1 exercise the weighted accumulation branch: shard count
    // must never move a bit of the RunResult.
    let cfg = base_cfg()
        .with_async(2, 12)
        .with_staleness_rule(StalenessRule::inverse());
    let one = run(cfg.clone());
    for shards in [2usize, 5, 16] {
        let sharded = run(cfg.clone().with_agg_shards(shards));
        assert_identical(&one, &sharded);
    }
}

#[test]
fn async_flags_round_trip_through_config_json() {
    let cfg = base_cfg()
        .with_async(3, 5)
        .with_staleness_rule(StalenessRule::Polynomial { a: 1.0 });
    let back =
        ExperimentConfig::from_json(&fedpaq::util::json::Json::parse(
            &cfg.to_json().to_string_pretty(),
        )
        .unwrap())
        .unwrap();
    assert_eq!(cfg, back);
}
