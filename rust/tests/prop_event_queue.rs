//! Property tests pinning the [`EventQueue`] heap to the historical
//! linear-scan event selection it replaced (driver: `fedpaq::util::prop`
//! — proptest is unavailable offline).
//!
//! The `AsyncSim` discrete-event loop popped the minimum
//! `(finish, version, slot, node)` job by scanning the in-flight vector;
//! the indexed queue must pop in *bit-identical* order — including under
//! exact `finish`-time ties, which the random job sets here manufacture
//! deliberately by drawing times from a coarse grid. Any divergence
//! would silently change commit contents and break every determinism
//! byte-diff leg downstream.

use fedpaq::simtime::{EventKey, EventQueue};
use fedpaq::util::prop::check;
use fedpaq::util::rng::Rng;

/// The reference implementation: the pre-heap linear scan, verbatim
/// semantics — minimum by the `(finish, version, slot, node)` total
/// order, removed via `swap_remove`.
fn scan_pop(jobs: &mut Vec<(EventKey, u64)>) -> Option<(EventKey, u64)> {
    let idx = jobs
        .iter()
        .enumerate()
        .min_by(|(_, (a, _)), (_, (b, _))| {
            a.finish
                .total_cmp(&b.finish)
                .then(a.version.cmp(&b.version))
                .then(a.slot.cmp(&b.slot))
                .then(a.node.cmp(&b.node))
        })
        .map(|(i, _)| i)?;
    Some(jobs.swap_remove(idx))
}

/// A random key drawing `finish` from a coarse grid so exact ties are
/// common, exercising the version/slot/node tie-break chain.
fn random_key(rng: &mut Rng) -> EventKey {
    EventKey {
        finish: rng.gen_range(0, 8) as f64 * 0.25,
        version: rng.gen_range(0, 4),
        slot: rng.gen_range(0, 6),
        node: rng.gen_range(0, 1000),
    }
}

#[test]
fn prop_heap_pop_order_matches_linear_scan() {
    check(300, 0xfed_e0, |rng| {
        let n = rng.gen_range(1, 120);
        let mut queue = EventQueue::new();
        let mut reference: Vec<(EventKey, u64)> = Vec::new();
        for i in 0..n {
            let key = random_key(rng);
            queue.push(key, i as u64);
            reference.push((key, i as u64));
        }
        assert_eq!(queue.len(), reference.len());
        while let Some((want_key, want_item)) = scan_pop(&mut reference) {
            let (got_key, got_item) = queue.pop().expect("heap drained early");
            // Bit-identical key, same payload — f64 compared via to_bits
            // so -0.0/0.0 or NaN drift can never slip through.
            assert_eq!(got_key.finish.to_bits(), want_key.finish.to_bits());
            assert_eq!(
                (got_key.version, got_key.slot, got_key.node),
                (want_key.version, want_key.slot, want_key.node)
            );
            assert_eq!(got_item, want_item);
        }
        assert!(queue.pop().is_none());
        assert!(queue.is_empty());
    });
}

#[test]
fn prop_heap_matches_scan_under_interleaved_push_pop() {
    // The sim interleaves dispatches (pushes) with arrivals (pops) inside
    // one round; order equivalence must hold at every intermediate state,
    // not just for a bulk load.
    check(200, 0xfed_e1, |rng| {
        let ops = rng.gen_range(1, 200);
        let mut queue = EventQueue::new();
        let mut reference: Vec<(EventKey, u64)> = Vec::new();
        let mut next_item = 0u64;
        for _ in 0..ops {
            if reference.is_empty() || rng.gen_range(0, 3) > 0 {
                let key = random_key(rng);
                queue.push(key, next_item);
                reference.push((key, next_item));
                next_item += 1;
            } else {
                let want = scan_pop(&mut reference).unwrap();
                let got = queue.pop().unwrap();
                assert_eq!(got.0.finish.to_bits(), want.0.finish.to_bits());
                assert_eq!(
                    (got.0.version, got.0.slot, got.0.node),
                    (want.0.version, want.0.slot, want.0.node)
                );
                assert_eq!(got.1, want.1);
            }
            assert_eq!(queue.len(), reference.len());
        }
    });
}

#[test]
fn prop_sorted_is_exactly_the_pop_order() {
    // `sorted()` is the canonical checkpoint serialization order; it must
    // agree with what a full drain would produce, without draining.
    check(150, 0xfed_e2, |rng| {
        let n = rng.gen_range(0, 80);
        let mut queue = EventQueue::new();
        let mut reference: Vec<(EventKey, u64)> = Vec::new();
        for i in 0..n {
            let key = random_key(rng);
            queue.push(key, i as u64);
            reference.push((key, i as u64));
        }
        let snapshot: Vec<(EventKey, u64)> =
            queue.sorted().into_iter().map(|(k, v)| (k, *v)).collect();
        let mut drained = Vec::new();
        while let Some(want) = scan_pop(&mut reference) {
            drained.push(want);
        }
        assert_eq!(snapshot.len(), drained.len());
        for ((sk, sv), (dk, dv)) in snapshot.iter().zip(&drained) {
            assert_eq!(sk.finish.to_bits(), dk.finish.to_bits());
            assert_eq!((sk.version, sk.slot, sk.node), (dk.version, dk.slot, dk.node));
            assert_eq!(sv, dv);
        }
    });
}
