//! Two-level aggregation trees over real localhost TCP sockets
//! (`net::TcpTree` root + `net::run_edge_retrying` edge leaders): the
//! ISSUE's acceptance gates —
//!
//! (a) a relay-mode (identity re-encode) tree with degenerate knobs
//!     commits **bit-identically** to the flat `TcpAsync` cluster, and
//!     the result is invariant to the edge count (1 vs 2);
//! (b) summed-mode partial re-encoding is byte-reproducible across
//!     repeat runs of the same seed, and the edge-side re-encode itself
//!     is deterministic and bit-budget-preserving per codec family;
//! (c) a mid-run edge-leader death retires the whole cohort's in-flight
//!     jobs back to the planner and the run still completes on the
//!     surviving edge.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::RunResult;
use fedpaq::data::DatasetKind;
use fedpaq::model::RustEngine;
use fedpaq::net::{
    partial_reencode, run_edge_retrying, run_leader, run_leader_tree, run_worker_retrying,
    EdgeOptions, WorkerOptions,
};
use fedpaq::ops::{EventSink, RunControl};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::{CodecSpec, Coding, Encoded};
use fedpaq::util::json::Json;
use fedpaq::util::rng::Rng;
use std::io::Write;
use std::net::TcpListener;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn cluster_cfg(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: "tcp-tree-it".into(),
        model: "logreg".into(),
        dataset: DatasetKind::Mnist08,
        n_nodes: 12,
        per_node: 60, // 720 samples >= the 480 eval slab below
        r: 6,
        tau: 2,
        t_total: 10,
        codec: CodecSpec::qsgd(2),
        lr: LrSchedule::Const { eta: 0.4 },
        ratio: 100.0,
        seed,
        eval_every: 1,
        engine: EngineKind::Rust,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: true,
        buffer_size: 0, // effective r — the degenerate full wave
        max_staleness: 0,
        staleness_rule: Default::default(),
        agg_shards: 1,
        down_codec: None,
        straggler: Default::default(),
        dataset_cap: 0,
    }
}

fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn leader_engine() -> RustEngine {
    RustEngine::new(fedpaq::model::ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 480)
        .unwrap()
}

/// A `Write` handle into a shared byte buffer, so a test can read back
/// the root's JSONL event stream.
#[derive(Clone)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Events of a given kind from a captured stream.
fn of_kind<'a>(events: &'a [Json], kind: &str) -> Vec<&'a Json> {
    events
        .iter()
        .filter(|e| e.get("event").and_then(Json::as_str) == Some(kind))
        .collect()
}

/// Root + `edge_opts.len()` edge leaders + their worker cohorts, all on
/// localhost threads. Edge `i` runs with `edge_opts[i]` (its cohort size
/// is `opts.workers`). Edge/worker errors are tolerated — an edge
/// running `--max-partials` death injection exits by design, and its
/// orphaned workers then lose their sockets.
fn run_tree(
    cfg: &ExperimentConfig,
    edge_opts: Vec<EdgeOptions>,
    summed: bool,
) -> (RunResult, Vec<Json>) {
    let root_addr = format!("127.0.0.1:{}", free_port());
    let n_edges = edge_opts.len();
    let mut threads = Vec::new();
    for opts in edge_opts {
        let root_addr = root_addr.clone();
        let edge_addr = format!("127.0.0.1:{}", free_port());
        let cohort = opts.workers;
        for _ in 0..cohort {
            let edge_addr = edge_addr.clone();
            threads.push(std::thread::spawn(move || {
                let _ = run_worker_retrying(
                    &edge_addr,
                    Path::new("artifacts"),
                    WorkerOptions::default(),
                    Duration::from_secs(30),
                );
            }));
        }
        threads.push(std::thread::spawn(move || {
            let _ = run_edge_retrying(&root_addr, &edge_addr, opts, Duration::from_secs(30));
        }));
    }
    let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
    let ctrl = RunControl {
        events: EventSink::to_writer(Box::new(buf.clone())),
        ..Default::default()
    };
    let mut engine = leader_engine();
    let res = run_leader_tree(
        cfg.clone(),
        &root_addr,
        n_edges,
        summed,
        &mut engine,
        Path::new("artifacts"),
        &ctrl,
    )
    .unwrap();
    for t in threads {
        t.join().unwrap();
    }
    let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
    let events = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    (res, events)
}

/// Flat `TcpAsync` cluster, the comparison baseline.
fn run_flat(cfg: &ExperimentConfig, n_workers: usize) -> RunResult {
    let addr = format!("127.0.0.1:{}", free_port());
    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                run_worker_retrying(
                    &addr,
                    Path::new("artifacts"),
                    WorkerOptions::default(),
                    Duration::from_secs(30),
                )
                .unwrap_or_else(|e| panic!("worker failed: {e}"));
            })
        })
        .collect();
    let mut engine = leader_engine();
    let res = run_leader(
        cfg.clone(),
        &addr,
        n_workers,
        &mut engine,
        Path::new("artifacts"),
        &RunControl::default(),
    )
    .unwrap();
    for w in workers {
        w.join().unwrap();
    }
    res
}

fn edges(n: usize, workers: usize) -> Vec<EdgeOptions> {
    (0..n)
        .map(|_| EdgeOptions { workers, ..Default::default() })
        .collect()
}

fn assert_bitwise_equal(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.params, b.params, "{what}: final models differ");
    assert_eq!(a.total_bits, b.total_bits, "{what}: uplink bits differ");
    assert_eq!(a.total_bits_down, b.total_bits_down, "{what}: downlink bits differ");
    assert_eq!(a.curve.points.len(), b.curve.points.len());
    for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
        assert_eq!(pa.round, pb.round);
        assert_eq!(
            pa.loss.to_bits(),
            pb.loss.to_bits(),
            "{what}: loss differs at k={}",
            pa.round
        );
        assert_eq!(pa.bits_up, pb.bits_up, "{what}: bits_up differs at k={}", pa.round);
    }
}

#[test]
fn relay_tree_matches_flat_async_bit_for_bit_and_edge_count_is_invariant() {
    // Identity re-encode (relay) + degenerate knobs: the root's planner
    // sees exactly the frames and commit boundaries a flat leader would,
    // so the committed models must not differ by one bit from the flat
    // TcpAsync run — and the 1-edge loopback must equal the 2-edge split.
    let cfg = cluster_cfg(61);
    let flat = run_flat(&cfg, 2);
    let (tree2, events2) = run_tree(&cfg, edges(2, 1), false);
    let (tree1, _) = run_tree(&cfg, edges(1, 2), false);

    assert_bitwise_equal(&flat, &tree2, "flat vs 2-edge tree");
    assert_bitwise_equal(&tree2, &tree1, "2-edge vs 1-edge tree");

    // Relay forwards every worker frame verbatim on the second hop, so
    // the split accounting must charge the same bits to both hops; the
    // flat run has no second hop at all.
    assert_eq!(flat.total_bits_edge_to_root, 0);
    assert_eq!(tree2.total_bits_edge_to_root, tree2.total_bits);
    for p in &tree2.curve.points {
        assert_eq!(p.bits_edge_to_root, p.bits_up);
    }
    // Both edges joined and their cohorts were seen.
    let joined = of_kind(&events2, "edge_joined");
    assert_eq!(joined.len(), 2, "expected two edge_joined events");
}

#[test]
fn summed_tree_is_byte_reproducible_across_repeat_runs() {
    // Lossy summed re-encode: never bit-identical to the flat run (f32
    // cast + edge-local addition order), but two runs of the same seed
    // must agree byte-for-byte — the edge re-encode draws from the
    // dedicated (seed, TREE_STREAM, edge_slot, version) RNG stream and
    // the FlushPartial wave markers pin the flush boundaries.
    let cfg = cluster_cfg(67);
    let (a, events) = run_tree(&cfg, edges(2, 1), true);
    let (b, _) = run_tree(&cfg, edges(2, 1), true);

    assert_bitwise_equal(&a, &b, "summed repeat runs");
    assert_eq!(a.total_bits_edge_to_root, b.total_bits_edge_to_root);
    // The summed hop actually compressed: one frame per cohort wave
    // instead of one per upload.
    assert!(
        a.total_bits_edge_to_root < a.total_bits,
        "summed edge hop ({}) should carry fewer bits than worker hop ({})",
        a.total_bits_edge_to_root,
        a.total_bits
    );
    // Every commit's cohort partials are on the event bus.
    assert!(!of_kind(&events, "partial_committed").is_empty());
    // And it still trains.
    let first = a.curve.points.first().unwrap().loss;
    let last = a.curve.points.last().unwrap().loss;
    assert!(last.is_finite() && last < first, "summed tree did not train");
}

#[test]
fn edge_death_mid_run_retires_cohort_and_run_completes() {
    // Edge 0 exits cleanly after 3 partials (`--max-partials`, the same
    // injector the CLI exposes). The root must notice the closed socket,
    // retire the whole cohort's in-flight jobs through CapacityFreed,
    // re-pin edge 0's nodes onto the survivor, and finish every commit.
    let cfg = ExperimentConfig {
        max_staleness: 6, // re-dispatched jobs arrive stale
        t_total: 10,      // 5 commits
        ..cluster_cfg(71)
    };
    let opts = vec![
        EdgeOptions { workers: 1, max_partials: Some(3), ..Default::default() },
        EdgeOptions { workers: 1, ..Default::default() },
    ];
    let (res, events) = run_tree(&cfg, opts, false);
    assert_eq!(res.rounds.len(), 5, "run did not complete all commits");
    let left = of_kind(&events, "edge_left");
    assert_eq!(left.len(), 1, "expected exactly one edge_left event");
    assert_eq!(left[0].get("edge").and_then(Json::as_usize), Some(0));
    assert!(left[0].get("jobs_retired").and_then(Json::as_usize).is_some());
    let first = res.curve.points.first().unwrap().loss;
    let last = res.curve.points.last().unwrap().loss;
    assert!(last.is_finite() && last < first, "churned tree run did not train");
}

#[test]
fn partial_reencode_is_deterministic_and_bit_preserving_per_family() {
    // The edge-side accumulate-then-re-encode contract, per built-in
    // family: byte-determinism given the seed stream, and the re-encoded
    // frame pays exactly the family's analytic bit budget (when it has
    // one) — a summed tree must not silently change a codec's wire cost.
    let p = 512usize;
    let cohort = 4usize;
    for (label, spec) in [
        ("identity", CodecSpec::Identity),
        ("qsgd_s2", CodecSpec::qsgd(2)),
        ("qsgd_s7_elias", CodecSpec::Qsgd { s: 7, coding: Coding::Elias }),
        ("topk_100", CodecSpec::top_k(100)),
        ("randk_100_seeded", CodecSpec::rand_k(100)),
        ("randk_100_elias", CodecSpec::RandK { k_permille: 100, seeded: false }),
        ("adaptive_b4", CodecSpec::adaptive(4)),
    ] {
        let codec = spec.build().unwrap();
        let xs: Vec<Vec<f32>> = (0..cohort)
            .map(|i| {
                (0..p)
                    .map(|j| ((i * p + j) as f32 * 0.31).sin() * 0.01)
                    .collect()
            })
            .collect();
        let encs: Vec<Encoded> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| codec.encode(x, &mut Rng::seed_from_u64(i as u64)))
            .collect();
        let run = || {
            let mut rng = Rng::from_coords(33, &[8, 0, 3]);
            partial_reencode(codec.as_ref(), &encs, p, &mut rng).unwrap()
        };
        let (fa, wa) = run();
        let (fb, wb) = run();
        assert_eq!(wa, cohort as f64, "{label}: wrong mass");
        assert_eq!(wa, wb, "{label}: mass not deterministic");
        assert_eq!(
            fa.buf.words(),
            fb.buf.words(),
            "{label}: re-encode not byte-deterministic"
        );
        assert_eq!(fa.bits(), fb.bits());
        if let Some(budget) = codec.analytic_bits(p) {
            assert_eq!(
                fa.bits(),
                budget,
                "{label}: re-encoded frame bits deviate from the analytic budget"
            );
        }
        // The frame round-trips through the family's own decoder.
        let decoded = codec.decode(&fa).unwrap();
        assert_eq!(decoded.len(), p, "{label}: decode width");
    }
}
