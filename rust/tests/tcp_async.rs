//! Buffered-async rounds over real localhost TCP sockets
//! (`net::TcpAsync`): the ISSUE's two acceptance gates —
//!
//! (a) with `buffer_size == r` and `max_staleness == 0` the committed
//!     model sequence is **bit-identical** to the barrier `Tcp` run (and
//!     to the in-process simulation), even though no global barrier is
//!     taken and socket arrival order is arbitrary;
//! (b) a delayed worker's uploads surface in later commits with a
//!     correct staleness stamp (visible in the per-round telemetry) and
//!     are damped by `StalenessRule::Polynomial` without breaking
//!     training.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::{RunResult, StalenessRule};
use fedpaq::data::DatasetKind;
use fedpaq::model::RustEngine;
use fedpaq::net::{run_leader, run_worker_retrying, WorkerOptions};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::CodecSpec;
use std::net::TcpListener;
use std::path::Path;
use std::time::Duration;

fn cluster_cfg(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: "tcp-async-it".into(),
        model: "logreg".into(),
        dataset: DatasetKind::Mnist08,
        n_nodes: 12,
        per_node: 60, // 720 samples >= the 480 eval slab below
        r: 6,
        tau: 2,
        t_total: 16,
        codec: CodecSpec::qsgd(2),
        lr: LrSchedule::Const { eta: 0.4 },
        ratio: 100.0,
        seed,
        eval_every: 1,
        engine: EngineKind::Rust,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: false,
        buffer_size: 0,
        max_staleness: 8,
        staleness_rule: Default::default(),
        agg_shards: 1,
    }
}

fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn leader_engine() -> RustEngine {
    RustEngine::new(fedpaq::model::ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 480)
        .unwrap()
}

/// Leader + worker threads on localhost; `delays[i]` injects a per-Work
/// sleep into worker `i` (a deterministic straggler).
fn run_cluster(cfg: &ExperimentConfig, delays: &[Option<Duration>]) -> RunResult {
    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    let workers: Vec<_> = delays
        .iter()
        .map(|&work_delay| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // Keep re-dialing until the leader is listening.
                run_worker_retrying(
                    &addr,
                    Path::new("artifacts"),
                    WorkerOptions { work_delay },
                    Duration::from_secs(30),
                )
                .unwrap_or_else(|e| panic!("worker failed: {e}"));
            })
        })
        .collect();
    let mut engine = leader_engine();
    let res = run_leader(
        cfg.clone(),
        &addr,
        delays.len(),
        &mut engine,
        Path::new("artifacts"),
    )
    .unwrap();
    for w in workers {
        w.join().unwrap();
    }
    res
}

#[test]
fn degenerate_async_tcp_matches_barrier_tcp_bit_for_bit() {
    // buffer_size == r (0 = full barrier's worth) and max_staleness == 0:
    // every commit waits for exactly its wave and sorts back into
    // sampling order, so the committed models must not differ by one bit
    // from the synchronous barrier run — regardless of socket arrival
    // order. Wall-clock times differ, so the comparison is over model,
    // losses and traffic.
    let sync_cfg = cluster_cfg(41);
    let async_cfg = ExperimentConfig {
        async_rounds: true,
        buffer_size: 0,
        max_staleness: 0,
        ..cluster_cfg(41)
    };
    let barrier = run_cluster(&sync_cfg, &[None, None]);
    let buffered = run_cluster(&async_cfg, &[None, None]);

    assert_eq!(barrier.params, buffered.params, "final models differ");
    assert_eq!(barrier.total_bits, buffered.total_bits);
    assert_eq!(barrier.curve.points.len(), buffered.curve.points.len());
    for (a, b) in barrier.curve.points.iter().zip(&buffered.curve.points) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss differs at k={}", a.round);
        assert_eq!(a.bits_up, b.bits_up);
    }
    // Degenerate async telemetry: nothing dropped, nothing stale.
    for r in &buffered.rounds {
        assert_eq!(r.dropped, 0);
        assert_eq!(r.staleness_max, 0);
        assert_eq!(r.staleness_mean, 0.0);
    }
    // And worker count still doesn't change results on the async path.
    let three = run_cluster(&async_cfg, &[None, None, None]);
    assert_eq!(barrier.params, three.params);
}

#[test]
fn delayed_worker_surfaces_with_staleness_and_polynomial_damping() {
    // b < r with one deliberately slow worker: the slow worker's uploads
    // must land in later commits carrying a positive staleness stamp
    // (bounded by max_staleness), be damped by the polynomial rule, and
    // training must still make progress.
    let cfg = ExperimentConfig {
        async_rounds: true,
        buffer_size: 2,
        max_staleness: 6,
        staleness_rule: StalenessRule::Polynomial { a: 1.0 },
        t_total: 24, // 12 commits
        ..cluster_cfg(43)
    };
    // 250 ms is a wide margin over CI scheduling jitter: the undelayed
    // worker fills buffers in well under that, so the straggler's
    // uploads are reliably stale when they surface.
    let res = run_cluster(&cfg, &[None, Some(Duration::from_millis(250))]);

    assert_eq!(res.rounds.len(), 12);
    // Every commit is a full buffer; staleness stays within the cap.
    for r in &res.rounds {
        assert!(r.staleness_max <= cfg.max_staleness, "cap violated at k={}", r.round);
        assert!(r.staleness_mean <= r.staleness_max as f64);
    }
    // The straggler actually surfaced: with the fast worker filling
    // buffers in microseconds and the slow one 250ms behind, some commit
    // must have aggregated a stale upload.
    assert!(
        res.rounds.iter().any(|r| r.staleness_max > 0),
        "no staleness observed — straggler never surfaced"
    );
    // Damped staleness-weighted training still converges.
    let first = res.curve.points.first().unwrap().loss;
    let last = res.curve.points.last().unwrap().loss;
    assert!(last < first * 0.9, "damped async-TCP did not train: {first} -> {last}");
    // Wall-clock time axis is monotone non-decreasing.
    let mut t = -1.0;
    for p in &res.curve.points {
        assert!(p.time >= t, "time went backwards");
        t = p.time;
    }
}
