//! Buffered-async rounds over real localhost TCP sockets
//! (`net::TcpAsync`): the ISSUE's two acceptance gates —
//!
//! (a) with `buffer_size == r` and `max_staleness == 0` the committed
//!     model sequence is **bit-identical** to the barrier `Tcp` run (and
//!     to the in-process simulation), even though no global barrier is
//!     taken and socket arrival order is arbitrary;
//! (b) a delayed worker's uploads surface in later commits with a
//!     correct staleness stamp (visible in the per-round telemetry) and
//!     are damped by `StalenessRule::Polynomial` without breaking
//!     training.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::{RunResult, StalenessRule};
use fedpaq::data::DatasetKind;
use fedpaq::model::RustEngine;
use fedpaq::net::{run_leader, run_worker_retrying, WorkerOptions};
use fedpaq::ops::{EventSink, RunControl};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::CodecSpec;
use fedpaq::util::json::Json;
use std::io::Write;
use std::net::TcpListener;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn cluster_cfg(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: "tcp-async-it".into(),
        model: "logreg".into(),
        dataset: DatasetKind::Mnist08,
        n_nodes: 12,
        per_node: 60, // 720 samples >= the 480 eval slab below
        r: 6,
        tau: 2,
        t_total: 16,
        codec: CodecSpec::qsgd(2),
        lr: LrSchedule::Const { eta: 0.4 },
        ratio: 100.0,
        seed,
        eval_every: 1,
        engine: EngineKind::Rust,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: false,
        buffer_size: 0,
        max_staleness: 8,
        staleness_rule: Default::default(),
        agg_shards: 1,
        down_codec: None,
        straggler: Default::default(),
        dataset_cap: 0,
    }
}

fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn leader_engine() -> RustEngine {
    RustEngine::new(fedpaq::model::ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 480)
        .unwrap()
}

/// Leader + worker threads on localhost; `delays[i]` injects a per-Work
/// sleep into worker `i` (a deterministic straggler).
fn run_cluster(cfg: &ExperimentConfig, delays: &[Option<Duration>]) -> RunResult {
    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    let workers: Vec<_> = delays
        .iter()
        .map(|&work_delay| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // Keep re-dialing until the leader is listening.
                run_worker_retrying(
                    &addr,
                    Path::new("artifacts"),
                    WorkerOptions { work_delay, ..Default::default() },
                    Duration::from_secs(30),
                )
                .unwrap_or_else(|e| panic!("worker failed: {e}"));
            })
        })
        .collect();
    let mut engine = leader_engine();
    let res = run_leader(
        cfg.clone(),
        &addr,
        delays.len(),
        &mut engine,
        Path::new("artifacts"),
        &RunControl::default(),
    )
    .unwrap();
    for w in workers {
        w.join().unwrap();
    }
    res
}

/// A `Write` handle into a shared byte buffer, so a test can read back
/// the leader's JSONL event stream.
#[derive(Clone)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Like [`run_cluster`], but with per-worker options, an optional
/// late-joining extra worker (spawned against the same leader after
/// `join_after`), and the leader's event stream captured. Worker errors
/// are tolerated for the late joiner (it may lose the race against a
/// short run) but fatal for the initial set.
fn run_cluster_churn(
    cfg: &ExperimentConfig,
    opts: Vec<WorkerOptions>,
    join_after: Option<Duration>,
) -> (RunResult, Vec<Json>) {
    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    let n_initial = opts.len();
    let mut workers: Vec<_> = opts
        .into_iter()
        .map(|o| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                run_worker_retrying(
                    &addr,
                    Path::new("artifacts"),
                    o,
                    Duration::from_secs(30),
                )
                .unwrap_or_else(|e| panic!("worker failed: {e}"));
            })
        })
        .collect();
    if let Some(delay) = join_after {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            std::thread::sleep(delay);
            // Best-effort: the joiner may lose the race against run end.
            let _ = run_worker_retrying(
                &addr,
                Path::new("artifacts"),
                WorkerOptions::default(),
                Duration::from_secs(5),
            );
        }));
    }
    let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
    let ctrl = RunControl {
        events: EventSink::to_writer(Box::new(buf.clone())),
        ..Default::default()
    };
    let mut engine = leader_engine();
    let res = run_leader(
        cfg.clone(),
        &addr,
        n_initial,
        &mut engine,
        Path::new("artifacts"),
        &ctrl,
    )
    .unwrap();
    for w in workers {
        w.join().unwrap();
    }
    let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
    let events = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    (res, events)
}

/// Events of a given kind from a captured stream.
fn of_kind<'a>(events: &'a [Json], kind: &str) -> Vec<&'a Json> {
    events
        .iter()
        .filter(|e| e.get("event").and_then(Json::as_str) == Some(kind))
        .collect()
}

#[test]
fn degenerate_async_tcp_matches_barrier_tcp_bit_for_bit() {
    // buffer_size == r (0 = full barrier's worth) and max_staleness == 0:
    // every commit waits for exactly its wave and sorts back into
    // sampling order, so the committed models must not differ by one bit
    // from the synchronous barrier run — regardless of socket arrival
    // order. Wall-clock times differ, so the comparison is over model,
    // losses and traffic.
    let sync_cfg = cluster_cfg(41);
    let async_cfg = ExperimentConfig {
        async_rounds: true,
        buffer_size: 0,
        max_staleness: 0,
        ..cluster_cfg(41)
    };
    let barrier = run_cluster(&sync_cfg, &[None, None]);
    let buffered = run_cluster(&async_cfg, &[None, None]);

    assert_eq!(barrier.params, buffered.params, "final models differ");
    assert_eq!(barrier.total_bits, buffered.total_bits);
    assert_eq!(barrier.curve.points.len(), buffered.curve.points.len());
    for (a, b) in barrier.curve.points.iter().zip(&buffered.curve.points) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss differs at k={}", a.round);
        assert_eq!(a.bits_up, b.bits_up);
    }
    // Degenerate async telemetry: nothing dropped, nothing stale.
    for r in &buffered.rounds {
        assert_eq!(r.dropped, 0);
        assert_eq!(r.staleness_max, 0);
        assert_eq!(r.staleness_mean, 0.0);
    }
    // And worker count still doesn't change results on the async path.
    let three = run_cluster(&async_cfg, &[None, None, None]);
    assert_eq!(barrier.params, three.params);
}

#[test]
fn delayed_worker_surfaces_with_staleness_and_polynomial_damping() {
    // b < r with one deliberately slow worker: the slow worker's uploads
    // must land in later commits carrying a positive staleness stamp
    // (bounded by max_staleness), be damped by the polynomial rule, and
    // training must still make progress.
    let cfg = ExperimentConfig {
        async_rounds: true,
        buffer_size: 2,
        max_staleness: 6,
        staleness_rule: StalenessRule::Polynomial { a: 1.0 },
        t_total: 24, // 12 commits
        ..cluster_cfg(43)
    };
    // 250 ms is a wide margin over CI scheduling jitter: the undelayed
    // worker fills buffers in well under that, so the straggler's
    // uploads are reliably stale when they surface.
    let res = run_cluster(&cfg, &[None, Some(Duration::from_millis(250))]);

    assert_eq!(res.rounds.len(), 12);
    // Every commit is a full buffer; staleness stays within the cap.
    for r in &res.rounds {
        assert!(r.staleness_max <= cfg.max_staleness, "cap violated at k={}", r.round);
        assert!(r.staleness_mean <= r.staleness_max as f64);
    }
    // The straggler actually surfaced: with the fast worker filling
    // buffers in microseconds and the slow one 250ms behind, some commit
    // must have aggregated a stale upload.
    assert!(
        res.rounds.iter().any(|r| r.staleness_max > 0),
        "no staleness observed — straggler never surfaced"
    );
    // Damped staleness-weighted training still converges.
    let first = res.curve.points.first().unwrap().loss;
    let last = res.curve.points.last().unwrap().loss;
    assert!(last < first * 0.9, "damped async-TCP did not train: {first} -> {last}");
    // Wall-clock time axis is monotone non-decreasing.
    let mut t = -1.0;
    for p in &res.curve.points {
        assert!(p.time >= t, "time went backwards");
        t = p.time;
    }
}

#[test]
fn worker_death_mid_run_retires_jobs_and_run_completes() {
    // One of two workers exits cleanly after 5 jobs (`max_jobs` — the
    // same injector `fedpaq worker --max-jobs` exposes). The async
    // leader must notice the close, retire that worker's in-flight jobs
    // back to the planner, re-dispatch them to the survivor, and finish
    // every commit — no hang, no error.
    let cfg = ExperimentConfig {
        async_rounds: true,
        buffer_size: 2,
        max_staleness: 6,
        t_total: 24, // 12 commits
        ..cluster_cfg(47)
    };
    let (res, events) = run_cluster_churn(
        &cfg,
        vec![
            WorkerOptions { max_jobs: Some(5), ..Default::default() },
            WorkerOptions::default(),
        ],
        None,
    );
    assert_eq!(res.rounds.len(), 12, "run did not complete all commits");
    // The death is on the event bus, attributed to worker 0.
    let left = of_kind(&events, "worker_left");
    assert_eq!(left.len(), 1, "expected exactly one worker_left event");
    assert_eq!(left[0].get("worker").and_then(Json::as_usize), Some(0));
    // Jobs dispatched after the 5th answer were lost and must have been
    // retired (the counter is also in the event for operators).
    assert!(left[0].get("jobs_retired").and_then(Json::as_usize).is_some());
    // Training still progressed on the surviving worker.
    let first = res.curve.points.first().unwrap().loss;
    let last = res.curve.points.last().unwrap().loss;
    assert!(last.is_finite() && last < first, "churned run did not train");
}

#[test]
fn late_joiner_is_absorbed_and_takes_over_after_a_death() {
    // One initial worker (slowed so the run outlasts the handshake), one
    // late joiner, and the initial worker dies after 6 jobs: the run can
    // only complete if the joiner was absorbed mid-run and the dead
    // worker's nodes were re-pinned onto it.
    let cfg = ExperimentConfig {
        async_rounds: true,
        buffer_size: 2,
        max_staleness: 6,
        t_total: 24, // 12 commits
        ..cluster_cfg(53)
    };
    let (res, events) = run_cluster_churn(
        &cfg,
        vec![WorkerOptions {
            work_delay: Some(Duration::from_millis(30)),
            max_jobs: Some(6),
            ..Default::default()
        }],
        Some(Duration::from_millis(50)),
    );
    assert_eq!(res.rounds.len(), 12, "run did not complete all commits");
    // Setup joins worker 0; the mid-run joiner is worker 1.
    let joined = of_kind(&events, "worker_joined");
    assert_eq!(joined.len(), 2, "expected setup join + mid-run join");
    assert_eq!(joined[1].get("worker").and_then(Json::as_usize), Some(1));
    let left = of_kind(&events, "worker_left");
    assert_eq!(left.len(), 1);
    assert_eq!(left[0].get("worker").and_then(Json::as_usize), Some(0));
    // Commits kept flowing after the handover.
    assert!(of_kind(&events, "commit").len() >= 12);
}
