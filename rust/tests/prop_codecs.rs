//! Property tests over every [`UpdateCodec`] implementation — the codec
//! trait contract: encode→decode identity on each codec's grid, exact
//! analytic bit accounting for fixed-width codings, and rejection of
//! decodes against a mismatched codec configuration.
//!
//! (Driver: `fedpaq::util::prop` — proptest is unavailable offline.)

use fedpaq::quant::{
    l2_norm, CodecSpec, Coding, IdentityCodec, QsgdCodec, TopKCodec, UpdateCodec,
};
use fedpaq::util::prop::check;
use fedpaq::util::rng::Rng;

/// One of every built-in codec family/coding combination.
fn all_codecs() -> Vec<Box<dyn UpdateCodec>> {
    vec![
        Box::new(IdentityCodec),
        Box::new(QsgdCodec { s: 1, coding: Coding::Naive }),
        Box::new(QsgdCodec { s: 7, coding: Coding::Naive }),
        Box::new(QsgdCodec { s: 7, coding: Coding::Elias }),
        Box::new(TopKCodec { k_permille: 100, coding: Coding::Naive }),
        Box::new(TopKCodec { k_permille: 250, coding: Coding::Elias }),
        Box::new(TopKCodec { k_permille: 1000, coding: Coding::Naive }),
    ]
}

fn random_vec(rng: &mut Rng, p: usize, scale: f32) -> Vec<f32> {
    (0..p).map(|_| (rng.gen_f32() * 2.0 - 1.0) * scale).collect()
}

/// Codec-specific decode contract: what "roundtrip identity on the grid"
/// means for each family.
fn assert_on_grid(codec: &dyn UpdateCodec, x: &[f32], y: &[f32]) {
    assert_eq!(x.len(), y.len());
    match codec.spec() {
        CodecSpec::Identity => assert_eq!(x, y, "identity must be exact"),
        CodecSpec::Qsgd { s, .. } => {
            let norm = l2_norm(x);
            for (i, &v) in y.iter().enumerate() {
                if norm == 0.0 {
                    assert_eq!(v, 0.0);
                    continue;
                }
                let lvl = v.abs() / norm * s as f32;
                assert!(
                    (lvl - lvl.round()).abs() < 1e-3,
                    "coord {i}: level {lvl} off the s={s} grid"
                );
                assert!(lvl.round() as u32 <= s, "coord {i}: level beyond s");
            }
        }
        CodecSpec::External { .. } => {
            unreachable!("all_codecs() yields only built-in codecs")
        }
        CodecSpec::TopK { .. } => {
            // Kept coordinates are exact copies; dropped ones are zero and
            // no kept-zero coordinate may hide a larger dropped one.
            let kept: Vec<usize> = (0..x.len()).filter(|&i| y[i] != 0.0).collect();
            for &i in &kept {
                assert_eq!(y[i], x[i], "kept coord {i} not exact");
            }
            let min_kept =
                kept.iter().map(|&i| x[i].abs()).fold(f32::INFINITY, f32::min);
            for i in 0..x.len() {
                if y[i] == 0.0 && !kept.is_empty() {
                    assert!(
                        x[i].abs() <= min_kept + 1e-12,
                        "dropped coord {i} (|{}|) larger than kept min {min_kept}",
                        x[i]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_every_codec_roundtrips_on_its_grid() {
    check(120, 0xc0dec_a, |rng| {
        let p = rng.gen_range(1, 1500);
        let x = random_vec(rng, p, 5.0);
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            assert_eq!(enc.p, p);
            assert_eq!(enc.spec, codec.spec());
            let y = codec.decode(&enc).unwrap_or_else(|e| {
                panic!("{:?} failed to decode its own encode: {e}", codec.spec())
            });
            assert_on_grid(codec.as_ref(), &x, &y);
        }
    });
}

#[test]
fn prop_fixed_width_codings_match_analytic_bits() {
    check(120, 0xc0dec_b, |rng| {
        let p = rng.gen_range(1, 3000);
        let x = random_vec(rng, p, 2.0);
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            match codec.analytic_bits(p) {
                // Fixed-width codings: the wire size is data-independent
                // and must match the analytic accounting exactly.
                Some(bits) => assert_eq!(
                    enc.bits(),
                    bits,
                    "{:?}: encoded {} != analytic {bits}",
                    codec.spec(),
                    enc.bits()
                ),
                // Elias codings are data-dependent; only sanity-bound them.
                None => assert!(enc.bits() > 0 || p == 0),
            }
        }
    });
}

#[test]
fn prop_decode_config_mismatch_is_rejected() {
    check(60, 0xc0dec_c, |rng| {
        let p = rng.gen_range(1, 400);
        let x = random_vec(rng, p, 1.0);
        let codecs = all_codecs();
        for (i, a) in codecs.iter().enumerate() {
            let enc = a.encode(&x, &mut rng.clone());
            for (j, b) in codecs.iter().enumerate() {
                let got = b.decode(&enc);
                if i == j {
                    assert!(got.is_ok(), "{:?} rejected its own encode", a.spec());
                } else {
                    assert!(
                        got.is_err(),
                        "{:?} decoded a buffer produced by {:?}",
                        b.spec(),
                        a.spec()
                    );
                }
            }
        }
    });
}

#[test]
fn prop_decode_into_reuses_buffer_and_matches_decode() {
    check(60, 0xc0dec_d, |rng| {
        let p = rng.gen_range(1, 800);
        let x = random_vec(rng, p, 3.0);
        let mut scratch: Vec<f32> = Vec::new();
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            codec.decode_into(&enc, &mut scratch).unwrap();
            assert_eq!(scratch.len(), p);
            assert_eq!(scratch, codec.decode(&enc).unwrap(), "{:?}", codec.spec());
        }
    });
}

#[test]
fn prop_decode_range_matches_full_decode_slice() {
    // The sharded-aggregation contract: for every codec, decoding any
    // `lo..hi` range — including the seek/skip-scan fast paths — is
    // bit-identical to slicing the full decode, and a disjoint cover of
    // ranges reassembles the full decode exactly.
    check(60, 0xc0dec_e, |rng| {
        let p = rng.gen_range(1, 800);
        let x = random_vec(rng, p, 3.0);
        let mut out: Vec<f32> = Vec::new();
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            let full = codec.decode(&enc).unwrap();
            // Random ranges, plus the degenerate empty and full ones.
            let mut lo = rng.gen_range(0, p + 1);
            let mut hi = rng.gen_range(0, p + 1);
            if lo > hi {
                std::mem::swap(&mut lo, &mut hi);
            }
            for (lo, hi) in [(lo, hi), (0, p), (0, 0), (p, p)] {
                codec.decode_range(&enc, lo, hi, &mut out).unwrap();
                assert_eq!(out, &full[lo..hi], "{:?} {lo}..{hi}", codec.spec());
            }
            // A disjoint cover reassembles the full vector.
            let cut_a = rng.gen_range(0, p + 1);
            let cut_b = rng.gen_range(cut_a, p + 1);
            let mut reassembled = Vec::with_capacity(p);
            for (lo, hi) in [(0, cut_a), (cut_a, cut_b), (cut_b, p)] {
                codec.decode_range(&enc, lo, hi, &mut out).unwrap();
                reassembled.extend_from_slice(&out);
            }
            assert_eq!(reassembled, full, "{:?}", codec.spec());
        }
    });
}
