//! Property tests over every [`UpdateCodec`] implementation — the codec
//! trait contract: encode→decode identity on each codec's grid, exact
//! analytic bit accounting for fixed-width codings, rejection of decodes
//! against a mismatched codec configuration, `decode_range` ≡
//! full-decode-slice, and the error-feedback statefulness laws.
//!
//! The CI **codec-conformance matrix** runs this suite once per codec
//! family with `FEDPAQ_CODEC_FILTER=<family>` (see
//! [`fedpaq::quant::family_enabled`]): `all_codecs()` and the
//! family-specific tests below honor the filter, so a broken codec names
//! itself in the job list.
//!
//! (Driver: `fedpaq::util::prop` — proptest is unavailable offline.)

use fedpaq::quant::bitstream::BitWriter;
use fedpaq::quant::{
    family_enabled, l2_norm, AdaptiveQsgdCodec, CodecSpec, Coding, Encoded,
    ErrorFeedbackCodec, IdentityCodec, QsgdCodec, RandKCodec, TopKCodec, UpdateCodec,
};
use fedpaq::util::prop::check;
use fedpaq::util::rng::Rng;

/// One of every built-in codec family/coding combination, restricted to
/// the families `FEDPAQ_CODEC_FILTER` enables (all, when unset). Fresh
/// instances per call, so stateful codecs start with empty memory.
fn all_codecs() -> Vec<Box<dyn UpdateCodec>> {
    let codecs: Vec<Box<dyn UpdateCodec>> = vec![
        Box::new(IdentityCodec),
        Box::new(QsgdCodec { s: 1, coding: Coding::Naive }),
        Box::new(QsgdCodec { s: 7, coding: Coding::Naive }),
        Box::new(QsgdCodec { s: 7, coding: Coding::Elias }),
        Box::new(TopKCodec { k_permille: 100, coding: Coding::Naive }),
        Box::new(TopKCodec { k_permille: 250, coding: Coding::Elias }),
        Box::new(TopKCodec { k_permille: 1000, coding: Coding::Naive }),
        Box::new(RandKCodec { k_permille: 100, seeded: true }),
        Box::new(RandKCodec { k_permille: 250, seeded: false }),
        Box::new(RandKCodec { k_permille: 1000, seeded: true }),
        Box::new(AdaptiveQsgdCodec { bits_per_coord: 4, coding: Coding::Naive }),
        Box::new(AdaptiveQsgdCodec { bits_per_coord: 6, coding: Coding::Elias }),
        // Error-feedback wrappers over each sparsifier family + QSGD.
        // Their wire_spec (= inner spec) must not collide with any bare
        // codec above, so the mismatch property stays meaningful.
        Box::new(ErrorFeedbackCodec::new(QsgdCodec { s: 3, coding: Coding::Naive })),
        Box::new(ErrorFeedbackCodec::new(TopKCodec {
            k_permille: 150,
            coding: Coding::Naive,
        })),
        Box::new(ErrorFeedbackCodec::new(RandKCodec { k_permille: 300, seeded: true })),
    ];
    codecs
        .into_iter()
        .filter(|c| family_enabled(c.spec().family()))
        .collect()
}

fn random_vec(rng: &mut Rng, p: usize, scale: f32) -> Vec<f32> {
    (0..p).map(|_| (rng.gen_f32() * 2.0 - 1.0) * scale).collect()
}

/// Codec-specific decode contract: what "roundtrip identity on the grid"
/// means for each family. Keyed on the **wire spec** — for transparent
/// wrappers (error feedback with empty memory) the frame is the inner
/// codec's frame of `x`, so the inner grid relation must hold.
fn assert_on_grid(codec: &dyn UpdateCodec, x: &[f32], y: &[f32]) {
    assert_eq!(x.len(), y.len());
    match codec.wire_spec() {
        CodecSpec::Identity => assert_eq!(x, y, "identity must be exact"),
        CodecSpec::Qsgd { s, .. } => assert_qsgd_grid(x, y, s),
        CodecSpec::AdaptiveQsgd { bits_per_coord, coding } => {
            let s = AdaptiveQsgdCodec { bits_per_coord, coding }.s_for(x.len());
            assert_qsgd_grid(x, y, s);
        }
        CodecSpec::External { .. } | CodecSpec::ErrorFeedback { .. } => {
            unreachable!("all_codecs() frames carry concrete built-in wire specs")
        }
        CodecSpec::TopK { .. } => {
            // Kept coordinates are exact copies; dropped ones are zero and
            // no kept-zero coordinate may hide a larger dropped one.
            let kept: Vec<usize> = (0..x.len()).filter(|&i| y[i] != 0.0).collect();
            for &i in &kept {
                assert_eq!(y[i], x[i], "kept coord {i} not exact");
            }
            let min_kept =
                kept.iter().map(|&i| x[i].abs()).fold(f32::INFINITY, f32::min);
            for i in 0..x.len() {
                if y[i] == 0.0 && !kept.is_empty() {
                    assert!(
                        x[i].abs() <= min_kept + 1e-12,
                        "dropped coord {i} (|{}|) larger than kept min {min_kept}",
                        x[i]
                    );
                }
            }
        }
        CodecSpec::RandK { k_permille, .. } => {
            // Kept coordinates are the original values scaled by exactly
            // p/k (one f32 multiply); the rest decode to zero.
            let p = x.len();
            let k = RandKCodec { k_permille, seeded: true }.k_of(p);
            let scale = p as f32 / k as f32;
            let mut kept = 0;
            for i in 0..p {
                if y[i] != 0.0 {
                    kept += 1;
                    assert_eq!(y[i], scale * x[i], "coord {i} not scale*x");
                }
            }
            assert!(kept <= k, "{kept} nonzero coords > k={k}");
        }
    }
}

fn assert_qsgd_grid(x: &[f32], y: &[f32], s: u32) {
    let norm = l2_norm(x);
    for (i, &v) in y.iter().enumerate() {
        if norm == 0.0 {
            assert_eq!(v, 0.0);
            continue;
        }
        let lvl = v.abs() / norm * s as f32;
        assert!(
            (lvl - lvl.round()).abs() < 1e-3,
            "coord {i}: level {lvl} off the s={s} grid"
        );
        assert!(lvl.round() as u32 <= s, "coord {i}: level beyond s");
    }
}

#[test]
fn prop_every_codec_roundtrips_on_its_grid() {
    check(120, 0xc0dec_a, |rng| {
        let p = rng.gen_range(1, 1500);
        let x = random_vec(rng, p, 5.0);
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            assert_eq!(enc.p, p);
            assert_eq!(enc.spec, codec.wire_spec());
            let y = codec.decode(&enc).unwrap_or_else(|e| {
                panic!("{:?} failed to decode its own encode: {e}", codec.spec())
            });
            assert_on_grid(codec.as_ref(), &x, &y);
        }
    });
}

#[test]
fn prop_fixed_width_codings_match_analytic_bits() {
    check(120, 0xc0dec_b, |rng| {
        let p = rng.gen_range(1, 3000);
        let x = random_vec(rng, p, 2.0);
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            match codec.analytic_bits(p) {
                // Fixed-width codings: the wire size is data-independent
                // and must match the analytic accounting exactly.
                Some(bits) => assert_eq!(
                    enc.bits(),
                    bits,
                    "{:?}: encoded {} != analytic {bits}",
                    codec.spec(),
                    enc.bits()
                ),
                // Elias codings are data-dependent; only sanity-bound them.
                None => assert!(enc.bits() > 0 || p == 0),
            }
        }
    });
}

#[test]
fn prop_decode_config_mismatch_is_rejected() {
    check(60, 0xc0dec_c, |rng| {
        let p = rng.gen_range(1, 400);
        let x = random_vec(rng, p, 1.0);
        let codecs = all_codecs();
        for a in codecs.iter() {
            let enc = a.encode(&x, &mut rng.clone());
            for b in codecs.iter() {
                let got = b.decode(&enc);
                // Transparent wrappers share their inner's wire format:
                // acceptance is keyed on the frame tag, not the config
                // identity.
                if a.wire_spec() == b.wire_spec() {
                    assert!(got.is_ok(), "{:?} rejected {:?}'s frame", b.spec(), a.spec());
                } else {
                    assert!(
                        got.is_err(),
                        "{:?} decoded a buffer produced by {:?}",
                        b.spec(),
                        a.spec()
                    );
                }
            }
        }
    });
}

#[test]
fn prop_decode_into_reuses_buffer_and_matches_decode() {
    check(60, 0xc0dec_d, |rng| {
        let p = rng.gen_range(1, 800);
        let x = random_vec(rng, p, 3.0);
        let mut scratch: Vec<f32> = Vec::new();
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            codec.decode_into(&enc, &mut scratch).unwrap();
            assert_eq!(scratch.len(), p);
            assert_eq!(scratch, codec.decode(&enc).unwrap(), "{:?}", codec.spec());
        }
    });
}

#[test]
fn prop_decode_range_matches_full_decode_slice() {
    // The sharded-aggregation contract: for every codec, decoding any
    // `lo..hi` range — including the seek/skip-scan fast paths — is
    // bit-identical to slicing the full decode, and a disjoint cover of
    // ranges reassembles the full decode exactly.
    check(60, 0xc0dec_e, |rng| {
        let p = rng.gen_range(1, 800);
        let x = random_vec(rng, p, 3.0);
        let mut out: Vec<f32> = Vec::new();
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            let full = codec.decode(&enc).unwrap();
            // Random ranges, plus the degenerate empty and full ones.
            let mut lo = rng.gen_range(0, p + 1);
            let mut hi = rng.gen_range(0, p + 1);
            if lo > hi {
                std::mem::swap(&mut lo, &mut hi);
            }
            for (lo, hi) in [(lo, hi), (0, p), (0, 0), (p, p)] {
                codec.decode_range(&enc, lo, hi, &mut out).unwrap();
                assert_eq!(out, &full[lo..hi], "{:?} {lo}..{hi}", codec.spec());
            }
            // A disjoint cover reassembles the full vector.
            let cut_a = rng.gen_range(0, p + 1);
            let cut_b = rng.gen_range(cut_a, p + 1);
            let mut reassembled = Vec::with_capacity(p);
            for (lo, hi) in [(0, cut_a), (cut_a, cut_b), (cut_b, p)] {
                codec.decode_range(&enc, lo, hi, &mut out).unwrap();
                reassembled.extend_from_slice(&out);
            }
            assert_eq!(reassembled, full, "{:?}", codec.spec());
        }
    });
}

// ---------------- error-feedback statefulness laws ----------------

#[test]
fn prop_error_feedback_identity_residuals_are_exactly_zero() {
    // Lossless inner codec ⇒ no compression error ⇒ the residual memory
    // is bit-exact zero after every round, for every node — and the
    // wrapped encode therefore equals the bare identity encode.
    if !family_enabled("error_feedback") {
        return;
    }
    check(40, 0xc0dec_f, |rng| {
        let ef = ErrorFeedbackCodec::new(IdentityCodec);
        let p = rng.gen_range(1, 600);
        for round in 0..4 {
            for node in [0usize, 2, 9] {
                let x = random_vec(rng, p, 4.0);
                let enc = ef.encode_node(node, &x, &mut rng.clone());
                assert_eq!(ef.decode(&enc).unwrap(), x, "round {round} node {node}");
                let res = ef.residual(node).unwrap();
                assert!(
                    res.iter().all(|&e| e == 0.0),
                    "round {round} node {node}: nonzero identity residual"
                );
            }
        }
        assert_eq!(ef.state_bytes(), 3 * p as u64 * 4);
        ef.reset_state();
        assert_eq!(ef.state_bytes(), 0);
    });
}

#[test]
fn prop_error_feedback_delegates_bits_variance_and_range_decode() {
    if !family_enabled("error_feedback") {
        return;
    }
    check(40, 0xc0dec_10, |rng| {
        let p = rng.gen_range(1, 800);
        let inners: Vec<Box<dyn UpdateCodec>> = vec![
            Box::new(QsgdCodec { s: rng.gen_range(1, 12) as u32, coding: Coding::Naive }),
            Box::new(TopKCodec {
                k_permille: rng.gen_range(1, 1001) as u16,
                coding: Coding::Elias,
            }),
            Box::new(RandKCodec {
                k_permille: rng.gen_range(1, 1001) as u16,
                seeded: true,
            }),
        ];
        for inner in inners {
            let spec = inner.spec();
            let (b_inner, q_inner) = (inner.analytic_bits(p), inner.variance_q(p));
            let ef = ErrorFeedbackCodec::new(inner);
            // analytic_bits / variance_q / wire_spec delegate verbatim.
            assert_eq!(ef.analytic_bits(p), b_inner, "{spec:?}");
            assert_eq!(ef.variance_q(p), q_inner, "{spec:?}");
            assert_eq!(ef.wire_spec(), spec);
            assert_eq!(ef.spec(), CodecSpec::ErrorFeedback { inner: Box::new(spec) });
            // decode_range delegates to the inner fast path bit-exactly.
            let x = random_vec(rng, p, 2.0);
            let enc = ef.encode_node(1, &x, &mut rng.clone());
            let full = ef.decode(&enc).unwrap();
            let mid = rng.gen_range(0, p + 1);
            let mut out = Vec::new();
            ef.decode_range(&enc, 0, mid, &mut out).unwrap();
            assert_eq!(out, &full[..mid]);
        }
    });
}

#[test]
fn prop_error_feedback_residual_law_and_determinism() {
    // The EF recurrence: e_t = (x_t + e_{t-1}) − decode(enc_t), exactly,
    // per node — and two fresh wrappers replaying the same history
    // produce bit-identical frames (what sim/TCP bit-parity rests on).
    if !family_enabled("error_feedback") {
        return;
    }
    check(30, 0xc0dec_11, |rng| {
        let p = rng.gen_range(1, 300);
        let a = ErrorFeedbackCodec::new(TopKCodec {
            k_permille: rng.gen_range(1, 1001) as u16,
            coding: Coding::Naive,
        });
        let b = ErrorFeedbackCodec::new(TopKCodec {
            k_permille: a.inner().k_permille,
            coding: Coding::Naive,
        });
        let node = rng.gen_range(0, 50);
        let mut prev_res = vec![0.0f32; p];
        for _ in 0..4 {
            let x = random_vec(rng, p, 3.0);
            let seed = rng.next_u64();
            let ea = a.encode_node(node, &x, &mut Rng::seed_from_u64(seed));
            let eb = b.encode_node(node, &x, &mut Rng::seed_from_u64(seed));
            assert_eq!(ea.buf.words(), eb.buf.words(), "history divergence");
            assert_eq!(ea.bits(), eb.bits());
            let dec = a.decode(&ea).unwrap();
            let res = a.residual(node).unwrap();
            for i in 0..p {
                assert_eq!(res[i], (x[i] + prev_res[i]) - dec[i], "coord {i}");
            }
            prev_res = res;
        }
    });
}

#[test]
fn prop_accumulate_range_matches_decode_range_add() {
    // The fused-aggregation contract: for every codec, accumulating any
    // `lo..hi` window at any valid weight — including the word-level,
    // LUT, and scatter-add fast paths — is bit-identical to the scratch
    // path (`decode_range` + weight-branched f64 widening add) over the
    // same prefilled accumulators. Prefills avoid `-0.0` (the trait's
    // accumulator guarantee), since sparse kernels skip implicit zeros.
    check(60, 0xc0dec_12, |rng| {
        let p = rng.gen_range(1, 800);
        let x = random_vec(rng, p, 3.0);
        let mut dec: Vec<f32> = Vec::new();
        for codec in all_codecs() {
            let enc = codec.encode(&x, &mut rng.clone());
            let mut lo = rng.gen_range(0, p + 1);
            let mut hi = rng.gen_range(0, p + 1);
            if lo > hi {
                std::mem::swap(&mut lo, &mut hi);
            }
            let weight = match rng.gen_range(0, 3) {
                0 => 1.0f64,
                1 => 0.5,
                _ => 1.0 / (1.0 + rng.gen_range(1, 10) as f64),
            };
            for (lo, hi) in [(lo, hi), (0, p), (0, 0), (p, p)] {
                // Aggregator-shaped prefill: +0.0 everywhere, plus a
                // nonzero variant to catch kernels that overwrite
                // instead of accumulate. Never -0.0.
                for prefill in [0.0f64, 0.25] {
                    let mut fused = vec![prefill; hi - lo];
                    let mut want = fused.clone();
                    codec
                        .accumulate_range(&enc, lo, hi, weight, &mut fused)
                        .unwrap_or_else(|e| {
                            panic!("{:?} {lo}..{hi} w={weight}: {e}", codec.spec())
                        });
                    codec.decode_range(&enc, lo, hi, &mut dec).unwrap();
                    if weight == 1.0 {
                        for (acc, &v) in want.iter_mut().zip(&dec) {
                            *acc += v as f64;
                        }
                    } else {
                        for (acc, &v) in want.iter_mut().zip(&dec) {
                            *acc += v as f64 * weight;
                        }
                    }
                    for (j, (f, w)) in fused.iter().zip(&want).enumerate() {
                        assert_eq!(
                            f.to_bits(),
                            w.to_bits(),
                            "{:?} {lo}..{hi} w={weight} coord {j}",
                            codec.spec()
                        );
                    }
                }
            }
            // Rejection surface: wrong accumulator length, bad ranges,
            // non-finite/non-positive weights — all before any add.
            let mut sum = vec![0.0f64; p];
            if p > 1 {
                assert!(codec
                    .accumulate_range(&enc, 0, p, 1.0, &mut sum[..p - 1])
                    .is_err());
            }
            assert!(codec.accumulate_range(&enc, 0, p + 1, 1.0, &mut sum).is_err());
            for w in [0.0f64, -1.0, f64::NAN, f64::INFINITY] {
                assert!(
                    codec.accumulate_range(&enc, 0, p, w, &mut sum).is_err(),
                    "{:?} accepted weight {w}",
                    codec.spec()
                );
                assert!(sum.iter().all(|&s| s == 0.0), "rejection touched sum");
            }
            // A frame cut in half rejects through the fused path exactly
            // like the decode path does (fixed-width exact-size checks,
            // Elias mid-stream truncation).
            let mut w = BitWriter::new();
            let mut r = enc.buf.reader();
            for _ in 0..enc.buf.len_bits() / 2 {
                w.write_bit(r.read_bit());
            }
            let cut = Encoded { buf: w.finish(), p, spec: enc.spec.clone() };
            assert!(
                codec.decode_range(&cut, 0, p, &mut dec).is_err(),
                "{:?} decoded a halved frame",
                codec.spec()
            );
            assert!(
                codec.accumulate_range(&cut, 0, p, 1.0, &mut sum).is_err(),
                "{:?} accumulated a halved frame",
                codec.spec()
            );
        }
    });
}
