//! Property-based tests over the coordinator's core invariants
//! (driver: `fedpaq::util::prop` — proptest is unavailable offline).
//!
//! Each `check(N, seed, ..)` runs N random cases; failures print a
//! replayable per-case seed. Codec-touching properties honor
//! `FEDPAQ_CODEC_FILTER` (the CI conformance matrix, see the `quant`
//! module docs): family-specific tests skip when their family is
//! filtered out, and the sharded-aggregation property draws its codec
//! pool from the enabled families only.

use fedpaq::config::ExperimentConfig;
use fedpaq::coordinator::sampler::sample_nodes;
use fedpaq::coordinator::{Aggregator, ShardPlan, StalenessRule};
use fedpaq::data::{BatchSampler, Partition};
use fedpaq::quant::{
    bitstream::BitWriter, elias, family_enabled, l2_norm, CodecSpec, Coding, Encoded,
    QsgdCodec, UpdateCodec,
};
use fedpaq::util::json::Json;
use fedpaq::util::prop::check;
use fedpaq::util::rng::Rng;

fn random_vec(rng: &mut Rng, p: usize, scale: f32) -> Vec<f32> {
    (0..p).map(|_| (rng.gen_f32() * 2.0 - 1.0) * scale).collect()
}

#[test]
fn prop_qsgd_decode_encode_levels_and_bits() {
    if !family_enabled("qsgd") {
        return;
    }
    check(200, 0xfed_aa, |rng| {
        let p = rng.gen_range(1, 3000);
        let s = rng.gen_range(1, 40) as u32;
        let x = random_vec(rng, p, 10.0);
        let q = QsgdCodec::new(s);
        let enc = q.encode(&x, &mut rng.clone());
        // Exact bit accounting under naive coding.
        assert_eq!(Some(enc.bits()), q.analytic_bits(p));
        // Decoded values on the quantization grid, |level| <= s.
        let norm = l2_norm(&x);
        for (i, v) in q.decode(&enc).unwrap().iter().enumerate() {
            if norm == 0.0 {
                assert_eq!(*v, 0.0);
                continue;
            }
            let lvl = v.abs() / norm * s as f32;
            assert!((lvl - lvl.round()).abs() < 1e-3, "coord {i}: lvl {lvl}");
            assert!(lvl.round() as u32 <= s, "coord {i}");
            // Sign preserved (zero-level loses the sign, which is fine).
            if lvl.round() > 0.0 {
                assert_eq!(v.signum(), x[i].signum(), "coord {i}");
            }
        }
    });
}

#[test]
fn prop_qsgd_error_within_deterministic_bound() {
    // |Q_i(x) - x_i| <= norm/s always (one quantization bin), since the
    // stochastic rounding picks an adjacent level.
    if !family_enabled("qsgd") {
        return;
    }
    check(150, 0xfed_ab, |rng| {
        let p = rng.gen_range(1, 800);
        let s = rng.gen_range(1, 16) as u32;
        let x = random_vec(rng, p, 3.0);
        let q = QsgdCodec::new(s);
        let (dec, _) = q.apply(&x, &mut rng.clone()).unwrap();
        let bin = l2_norm(&x) / s as f32 + 1e-5;
        for (i, (&xi, &qi)) in x.iter().zip(&dec).enumerate() {
            assert!(
                (xi - qi).abs() <= bin,
                "coord {i}: |{xi} - {qi}| > bin {bin}"
            );
        }
    });
}

#[test]
fn prop_elias_roundtrip_arbitrary_u64() {
    check(300, 0xfed_ac, |rng| {
        let n = rng.gen_range(1, 20);
        let vals: Vec<u64> = (0..n)
            .map(|_| {
                let bits = rng.gen_range(0, 40);
                (rng.next_u64() >> (63 - bits)).max(1)
            })
            .collect();
        let mut w = BitWriter::new();
        let mut expect_len = 0;
        for &v in &vals {
            elias::encode_omega(&mut w, v);
            expect_len += elias::omega_len(v);
        }
        let buf = w.finish();
        assert_eq!(buf.len_bits(), expect_len);
        let mut r = buf.reader();
        for &v in &vals {
            assert_eq!(elias::decode_omega(&mut r), v);
        }
    });
}

#[test]
fn prop_elias_coded_upload_decodes_identically() {
    if !family_enabled("qsgd") {
        return;
    }
    check(100, 0xfed_ad, |rng| {
        let p = rng.gen_range(1, 500);
        let s = rng.gen_range(1, 64) as u32;
        let x = random_vec(rng, p, 1.0);
        let naive = QsgdCodec { s, coding: Coding::Naive };
        let elias_q = QsgdCodec { s, coding: Coding::Elias };
        // Same RNG stream -> same stochastic levels -> identical decode.
        let seed = rng.next_u64();
        let en = naive.encode(&x, &mut Rng::seed_from_u64(seed));
        let ee = elias_q.encode(&x, &mut Rng::seed_from_u64(seed));
        assert_eq!(naive.decode(&en).unwrap(), elias_q.decode(&ee).unwrap());
    });
}

#[test]
fn prop_partition_is_exact_cover() {
    check(100, 0xfed_ae, |rng| {
        let n_nodes = rng.gen_range(1, 40);
        let per_node = rng.gen_range(1, 60);
        let extra = rng.gen_range(0, 50);
        let n_samples = n_nodes * per_node + extra;
        let part = Partition::iid(n_samples, n_nodes, per_node);
        let mut seen = vec![false; n_samples];
        for node in 0..n_nodes {
            assert_eq!(part.shard(node).len(), per_node);
            for i in part.shard(node).iter() {
                assert!(!seen[i], "sample {i} in two shards");
                seen[i] = true;
            }
        }
        assert_eq!(seen.iter().filter(|&&b| b).count(), n_nodes * per_node);
    });
}

#[test]
fn prop_node_sampling_uniform_without_replacement() {
    check(150, 0xfed_af, |rng| {
        let n = rng.gen_range(1, 100);
        let r = rng.gen_range(1, n + 1);
        let nodes = sample_nodes(n, r, rng.next_u64(), rng.gen_range(0, 1000));
        assert_eq!(nodes.len(), r);
        let mut sorted = nodes.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), r, "duplicates");
        assert!(nodes.iter().all(|&i| i < n));
    });
}

#[test]
fn prop_batch_sampler_deterministic_and_in_range() {
    check(150, 0xfed_b0, |rng| {
        let b = rng.gen_range(1, 64);
        let shard = rng.gen_range(1, 500);
        let seed = rng.next_u64();
        let s = BatchSampler::new(seed, b);
        let (node, round, step) =
            (rng.gen_range(0, 50), rng.gen_range(0, 100), rng.gen_range(0, 50));
        let a = s.sample(node, round, step, shard);
        let b2 = s.sample(node, round, step, shard);
        assert_eq!(a, b2);
        assert!(a.iter().all(|&i| i < shard));
    });
}

#[test]
fn prop_sharded_aggregation_bit_identical_to_single_shard() {
    // The aggregate module's determinism contract: for any batch of
    // uploads (any codec, any staleness weights), any shard count yields
    // byte-for-byte the model the sequential single-shard loop produces —
    // sums, ledgers and the applied parameters alike.
    // One spec constructor per family member; the conformance matrix's
    // filter narrows the pool (and skips the test if nothing is left).
    type SpecGen = fn(&mut Rng) -> CodecSpec;
    let all: [SpecGen; 9] = [
        |_| CodecSpec::Identity,
        |rng| CodecSpec::qsgd(rng.gen_range(1, 16) as u32),
        |rng| CodecSpec::Qsgd { s: rng.gen_range(1, 16) as u32, coding: Coding::Elias },
        |rng| CodecSpec::TopK {
            k_permille: rng.gen_range(1, 1001) as u16,
            coding: Coding::Naive,
        },
        |rng| CodecSpec::TopK {
            k_permille: rng.gen_range(1, 1001) as u16,
            coding: Coding::Elias,
        },
        |rng| CodecSpec::RandK {
            k_permille: rng.gen_range(1, 1001) as u16,
            seeded: rng.gen_bool(0.5),
        },
        |rng| CodecSpec::adaptive(rng.gen_range(2, 12) as u8),
        |rng| {
            CodecSpec::error_feedback(CodecSpec::TopK {
                k_permille: rng.gen_range(1, 1001) as u16,
                coding: Coding::Naive,
            })
        },
        |rng| {
            CodecSpec::error_feedback(CodecSpec::RandK {
                k_permille: rng.gen_range(1, 1001) as u16,
                seeded: true,
            })
        },
    ];
    let pool: Vec<SpecGen> = all
        .into_iter()
        .filter(|g| family_enabled(g(&mut Rng::seed_from_u64(0)).family()))
        .collect();
    if pool.is_empty() {
        return;
    }
    check(60, 0xfed_b4, |rng| {
        let p = rng.gen_range(1, 2500);
        let spec = pool[rng.gen_range(0, pool.len())](rng);
        let codec: Box<dyn UpdateCodec> = spec.build().unwrap();
        let rule = match rng.gen_range(0, 3) {
            0 => StalenessRule::Uniform,
            1 => StalenessRule::inverse(),
            _ => StalenessRule::Polynomial { a: 0.5 },
        };
        let n_uploads = rng.gen_range(1, 7);
        let uploads: Vec<(Encoded, f64)> = (0..n_uploads)
            .map(|_| {
                let x = random_vec(rng, p, 2.0);
                let staleness = rng.gen_range(0, 6);
                let enc = codec.encode(&x, &mut rng.clone());
                rng.next_u64(); // decorrelate the per-upload RNG clones
                (enc, rule.weight(staleness))
            })
            .collect();
        let batch: Vec<(&Encoded, f64)> = uploads.iter().map(|(e, w)| (e, *w)).collect();
        let params0 = random_vec(rng, p, 1.0);

        // Reference: the sequential streaming path.
        let mut reference = Aggregator::new(p);
        for &(enc, w) in &batch {
            reference.push_weighted(codec.as_ref(), enc, w).unwrap();
        }
        let mut want = params0.clone();
        reference.apply(&mut want).unwrap();

        for shards in [2, 3, rng.gen_range(2, 24)] {
            let plan = ShardPlan::new(p, shards);
            let mut agg = Aggregator::new(p);
            agg.push_batch(codec.as_ref(), &batch, &plan).unwrap();
            assert_eq!(agg.count(), reference.count(), "shards={shards}");
            assert_eq!(agg.upload_bits(), reference.upload_bits());
            assert_eq!(
                agg.weight_sum().to_bits(),
                reference.weight_sum().to_bits(),
                "shards={shards}"
            );
            let mut got = params0.clone();
            agg.apply_sharded(&mut got, &plan).unwrap();
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "shards={shards} param {i}: {a} != {b}"
                );
            }
        }
    });
}

#[test]
fn prop_config_json_roundtrip() {
    check(120, 0xfed_b1, |rng| {
        let mut cfg = ExperimentConfig::fig1_logreg_base();
        cfg.n_nodes = rng.gen_range(1, 100);
        cfg.r = rng.gen_range(1, cfg.n_nodes + 1);
        cfg.tau = rng.gen_range(1, 60);
        cfg.t_total = cfg.tau * rng.gen_range(1, 50);
        cfg.seed = rng.next_u64();
        cfg.ratio = rng.gen_f64() * 1000.0 + 1.0;
        cfg.codec = match rng.gen_range(0, 7) {
            0 => CodecSpec::Identity,
            1 => CodecSpec::qsgd(rng.gen_range(1, 100) as u32),
            2 => CodecSpec::Qsgd {
                s: rng.gen_range(1, 100) as u32,
                coding: Coding::Elias,
            },
            3 => CodecSpec::RandK {
                k_permille: rng.gen_range(1, 1001) as u16,
                seeded: rng.gen_bool(0.5),
            },
            4 => CodecSpec::AdaptiveQsgd {
                bits_per_coord: rng.gen_range(2, 33) as u8,
                coding: if rng.gen_bool(0.5) { Coding::Elias } else { Coding::Naive },
            },
            5 => CodecSpec::error_feedback(match rng.gen_range(0, 3) {
                0 => CodecSpec::top_k(rng.gen_range(1, 1001) as u16),
                1 => CodecSpec::rand_k(rng.gen_range(1, 1001) as u16),
                _ => CodecSpec::qsgd(rng.gen_range(1, 100) as u32),
            }),
            _ => CodecSpec::TopK {
                k_permille: rng.gen_range(1, 1001) as u16,
                coding: if rng.gen_bool(0.5) { Coding::Elias } else { Coding::Naive },
            },
        };
        cfg.agg_shards = rng.gen_range(1, 17);
        if rng.gen_bool(0.5) {
            cfg.async_rounds = true;
            cfg.buffer_size = rng.gen_range(0, cfg.r + 1); // 0 = full barrier
            cfg.max_staleness = rng.gen_range(0, 20);
            cfg.staleness_rule = if rng.gen_bool(0.5) {
                StalenessRule::Uniform
            } else {
                // Quarter-step exponents are exact in f64 and in the JSON
                // decimal round-trip.
                StalenessRule::Polynomial { a: rng.gen_range(1, 9) as f64 * 0.25 }
            };
        }
        let cfg = cfg.validated().unwrap();
        let text = cfg.to_json().to_string_pretty();
        let back = ExperimentConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(cfg, back);
    });
}

#[test]
fn prop_json_parser_roundtrips_random_documents() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.gen_range(0, 4) } else { rng.gen_range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_bool(0.5)),
            2 => Json::Num((rng.gen_f64() * 2e6).round() / 1e3),
            3 => Json::Str(
                (0..rng.gen_range(0, 12))
                    .map(|_| {
                        let c = rng.gen_range(32, 127) as u8 as char;
                        if c == '\\' { 'x' } else { c }
                    })
                    .collect(),
            ),
            4 => Json::Arr((0..rng.gen_range(0, 5)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(0, 5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    check(200, 0xfed_b2, |rng| {
        let doc = random_json(rng, 0);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
        assert_eq!(doc, back, "\n{text}");
    });
}

#[test]
fn prop_wire_messages_roundtrip() {
    use fedpaq::net::proto::{ModelPayload, ToLeader, ToWorker};
    check(150, 0xfed_b3, |rng| {
        let p = rng.gen_range(1, 400);
        // Alternate raw broadcasts and compressed delta chains so both
        // wire-v3 payload shapes survive the roundtrip.
        let chain = rng.gen_bool(0.5);
        let payload = if chain {
            let q = QsgdCodec::new(rng.gen_range(1, 16) as u32);
            let n_links = rng.gen_range(1, 4);
            ModelPayload::Chain {
                base_version: rng.next_u64() % 1000,
                links: (0..n_links)
                    .map(|_| q.encode(&random_vec(rng, p, 2.0), &mut rng.clone()))
                    .collect(),
            }
        } else {
            ModelPayload::Raw(random_vec(rng, p, 1.0))
        };
        let msg = ToWorker::Work {
            version: rng.next_u64() % 1000,
            node: rng.next_u64() % 50,
            payload,
            lrs: {
                let n_lrs = rng.gen_range(1, 8);
                random_vec(rng, n_lrs, 0.1)
            },
        };
        let bytes = msg.encode();
        let back = ToWorker::decode(&bytes).unwrap();
        // Re-encoding the decoded frame must reproduce the exact bytes
        // (covers the payload, whose Encoded links aren't PartialEq).
        assert_eq!(back.encode(), bytes);
        match (back, &msg) {
            (
                ToWorker::Work { version, node, lrs, .. },
                ToWorker::Work { version: v2, node: n2, lrs: l2, .. },
            ) => {
                assert_eq!(version, *v2);
                assert_eq!(node, *n2);
                assert_eq!(&lrs, l2);
            }
            _ => panic!(),
        }
        let q = QsgdCodec::new(rng.gen_range(1, 16) as u32);
        let enc = q.encode(&random_vec(rng, p, 2.0), &mut rng.clone());
        let want = q.decode(&enc).unwrap();
        let up = ToLeader::Update {
            version: 1,
            node: 2,
            enc,
            compute_ms: 3.25,
            decode_ms: 0.5,
        };
        match ToLeader::decode(&up.encode()).unwrap() {
            ToLeader::Update { enc, compute_ms, decode_ms, .. } => {
                assert_eq!(q.decode(&enc).unwrap(), want);
                assert_eq!(compute_ms, 3.25);
                assert_eq!(decode_ms, 0.5);
            }
            _ => panic!(),
        }
    });
}
