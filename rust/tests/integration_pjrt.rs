//! Integration tests over the PJRT runtime: AOT artifacts must load,
//! execute, agree with the pure-rust oracle, and train end-to-end.
//!
//! All tests skip gracefully when `make artifacts` has not run (CI before
//! the python stage). PJRT clients are process-global state in the CPU
//! plugin, so every test shares one client via a thread-local.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::Server;
use fedpaq::data::DatasetKind;
use fedpaq::figures::{zoo_kind, Runner};
use fedpaq::model::{Engine, LabelBatch, RustEngine};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::{l2_norm, CodecSpec};
use fedpaq::runtime::{cpu_client, PjrtEngine, QuantizeKernel};
use fedpaq::util::rng::Rng;
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn client() -> xla::PjRtClient {
    cpu_client().expect("PJRT CPU client")
}

#[test]
fn logreg_engine_matches_rust_oracle() {
    let dir = require_artifacts!();
    let client = client();
    let mut pjrt = PjrtEngine::load(&client, &dir, "logreg").unwrap();
    let mut oracle = RustEngine::new(zoo_kind("logreg").unwrap().0, 10, 10_000).unwrap();

    // Identical zero init.
    let p0 = pjrt.init_params().unwrap();
    assert_eq!(p0, oracle.init_params().unwrap());
    assert_eq!(p0.len(), 785);

    // Same loss on a random batch (PJRT loss program is eval_n-shaped, so
    // build an eval-sized slab).
    let mut rng = Rng::seed_from_u64(1);
    let n = 10_000;
    let x: Vec<f32> = (0..n * 784).map(|_| rng.gen_f32() - 0.5).collect();
    let y: Vec<f32> = (0..n).map(|_| (rng.gen_bool(0.5)) as u8 as f32).collect();
    let lp = pjrt.eval_loss(&p0, &x, LabelBatch::F32(&y)).unwrap();
    let lo = oracle.eval_loss(&p0, &x, LabelBatch::F32(&y)).unwrap();
    assert!((lp - lo).abs() < 1e-5, "pjrt {lp} vs oracle {lo}");

    // One SGD step must agree coordinate-wise.
    let xb: Vec<f32> = x[..10 * 784].to_vec();
    let yb: Vec<f32> = y[..10].to_vec();
    let sp = pjrt.sgd_step(&p0, &xb, LabelBatch::F32(&yb), 0.5).unwrap();
    let so = oracle.sgd_step(&p0, &xb, LabelBatch::F32(&yb), 0.5).unwrap();
    let max_diff = sp
        .iter()
        .zip(&so)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-5, "step divergence {max_diff}");

    // Chained local SGD == looped single steps.
    let tau = 4;
    let xs: Vec<f32> = x[..tau * 10 * 784].to_vec();
    let ys: Vec<f32> = y[..tau * 10].to_vec();
    let lrs = vec![0.3f32; tau];
    let chained = pjrt.local_sgd_chained(&p0, &xs, LabelBatch::F32(&ys), &lrs).unwrap();
    let mut looped = p0.clone();
    for t in 0..tau {
        looped = oracle
            .sgd_step(
                &looped,
                &xs[t * 7840..(t + 1) * 7840],
                LabelBatch::F32(&ys[t * 10..(t + 1) * 10]),
                0.3,
            )
            .unwrap();
    }
    let max_diff = chained
        .iter()
        .zip(&looped)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 5e-5, "chained divergence {max_diff}");
}

#[test]
fn mlp_engine_loss_matches_rust_oracle() {
    let dir = require_artifacts!();
    let client = client();
    let (kind, batch, eval_n) = zoo_kind("mlp_fashion").unwrap();
    let mut pjrt = PjrtEngine::load(&client, &dir, "mlp_fashion").unwrap();
    let mut oracle = RustEngine::new(kind, batch, eval_n).unwrap();

    // Shared params: use the PJRT (JAX) init on both engines.
    let p0 = pjrt.init_params().unwrap();
    let mut rng = Rng::seed_from_u64(2);
    let x: Vec<f32> = (0..eval_n * 784).map(|_| rng.gen_f32() - 0.5).collect();
    let y: Vec<i32> = (0..eval_n).map(|_| rng.gen_range(0, 10) as i32).collect();
    let lp = pjrt.eval_loss(&p0, &x, LabelBatch::I32(&y)).unwrap();
    let lo = oracle.eval_loss(&p0, &x, LabelBatch::I32(&y)).unwrap();
    assert!(
        (lp - lo).abs() / lo.abs().max(1.0) < 1e-4,
        "pjrt {lp} vs oracle {lo}"
    );

    // One SGD step agrees (different backprop implementations).
    let xb: Vec<f32> = x[..batch * 784].to_vec();
    let yb: Vec<i32> = y[..batch].to_vec();
    let sp = pjrt.sgd_step(&p0, &xb, LabelBatch::I32(&yb), 0.1).unwrap();
    let so = oracle.sgd_step(&p0, &xb, LabelBatch::I32(&yb), 0.1).unwrap();
    let rel: f32 = {
        let num: f32 = sp.iter().zip(&so).map(|(&a, &b)| (a - b) * (a - b)).sum();
        let den: f32 = so.iter().map(|&b| b * b).sum();
        (num / den).sqrt()
    };
    assert!(rel < 1e-4, "relative step divergence {rel}");
}

#[test]
fn pallas_quantizer_matches_rust_codec_grid() {
    let dir = require_artifacts!();
    let client = client();
    let kernel = QuantizeKernel::load(&client, &dir).unwrap();
    let mut rng = Rng::seed_from_u64(3);
    let x: Vec<f32> = (0..kernel.p).map(|_| rng.gen_f32() * 4.0 - 2.0).collect();
    let u: Vec<f32> = (0..kernel.p).map(|_| rng.gen_f32()).collect();
    for s in [1u32, 5, 10] {
        let out = kernel.run(&x, &u, s as f32).unwrap();
        // Same stochastic-rounding formula as the rust codec.
        let norm = l2_norm(&x);
        for i in 0..kernel.p {
            let a = x[i].abs() / norm * s as f32;
            let lo = a.floor();
            let level = lo + (u[i] < a - lo) as u32 as f32;
            let want = norm * x[i].signum() * level / s as f32;
            assert!(
                (want - out[i]).abs() <= 2e-4 * norm.max(1.0),
                "s={s} coord {i}: kernel {} vs codec {want}",
                out[i]
            );
        }
    }
}

#[test]
fn transformer_artifacts_execute_and_learn_direction() {
    let dir = require_artifacts!();
    let client = client();
    let mut eng = PjrtEngine::load(&client, &dir, "transformer").unwrap();
    let p0 = eng.init_params().unwrap();
    assert_eq!(p0.len(), eng.param_count());

    let mut rng = Rng::seed_from_u64(4);
    let b = eng.batch();
    let seq = 32;
    // Constant-successor sequences: highly learnable.
    let mk = |rng: &mut Rng, n: usize| -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let start = rng.gen_range(0, 64);
            for t in 0..seq {
                xs.push(((start + t) % 64) as f32);
                ys.push(((start + t + 1) % 64) as i32);
            }
        }
        (xs, ys)
    };
    let (ex, ey) = mk(&mut rng, eng.eval_n());
    let l0 = eng.eval_loss(&p0, &ex, LabelBatch::I32(&ey)).unwrap();
    assert!((l0 - (64f32).ln()).abs() < 0.5, "fresh LM loss {l0}");

    let mut p = p0;
    for step in 0..60 {
        let (xb, yb) = mk(&mut rng, b);
        p = eng
            .local_sgd_chained(&p, &xb, LabelBatch::I32(&yb), &[0.1])
            .unwrap();
        let _ = step;
    }
    let l1 = eng.eval_loss(&p, &ex, LabelBatch::I32(&ey)).unwrap();
    assert!(l1 < l0 * 0.8, "LM did not learn: {l0} -> {l1}");
}

#[test]
fn pjrt_fedpaq_run_decreases_loss_and_matches_shape() {
    let dir = require_artifacts!();
    let mut runner = Runner::new(EngineKind::Pjrt, &dir);
    let cfg = ExperimentConfig {
        name: "it".into(),
        model: "logreg".into(),
        dataset: DatasetKind::Mnist08,
        n_nodes: 50,
        per_node: 200,
        r: 10,
        tau: 5,
        t_total: 40,
        codec: CodecSpec::qsgd(1),
        lr: LrSchedule::Const { eta: 0.2 },
        ratio: 100.0,
        seed: 11,
        eval_every: 2,
        engine: EngineKind::Pjrt,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: false,
        buffer_size: 0,
        max_staleness: 8,
        staleness_rule: Default::default(),
        agg_shards: 1,
        down_codec: None,
        straggler: Default::default(),
        dataset_cap: 0,
    };
    let res = runner.run_config(cfg, fedpaq::ops::RunControl::default()).unwrap();
    let first = res.curve.points.first().unwrap().loss;
    let last = res.curve.points.last().unwrap().loss;
    assert!(last < first * 0.7, "{first} -> {last}");
    assert_eq!(res.rounds.len(), 8);
}

#[test]
fn pjrt_and_rust_engines_agree_on_full_logreg_run() {
    let dir = require_artifacts!();
    let cfg = ExperimentConfig {
        name: "parity".into(),
        model: "logreg".into(),
        dataset: DatasetKind::Mnist08,
        n_nodes: 50,
        per_node: 200,
        r: 5,
        tau: 3,
        t_total: 12,
        codec: CodecSpec::qsgd(2),
        lr: LrSchedule::Const { eta: 0.3 },
        ratio: 100.0,
        seed: 21,
        eval_every: 4,
        engine: EngineKind::Pjrt,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: false,
        buffer_size: 0,
        max_staleness: 8,
        staleness_rule: Default::default(),
        agg_shards: 1,
        down_codec: None,
        straggler: Default::default(),
        dataset_cap: 0,
    };
    let client = client();
    let mut pjrt = PjrtEngine::load(&client, &dir, "logreg").unwrap();
    let res_pjrt = Server::new(cfg.clone(), &mut pjrt).unwrap().run().unwrap();
    let mut oracle = RustEngine::new(zoo_kind("logreg").unwrap().0, 10, 10_000).unwrap();
    let res_rust = Server::new(cfg.with_engine(EngineKind::Rust), &mut oracle)
        .unwrap()
        .run()
        .unwrap();
    // Same seeds -> same batches, same sampling, same quantization stream.
    // Engines differ only in f32 rounding, which quantization re-grids, so
    // trajectories stay extremely close over a short horizon.
    let max_diff = res_pjrt
        .params
        .iter()
        .zip(&res_rust.params)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 2e-3, "engine divergence {max_diff}");
    assert_eq!(res_pjrt.total_bits, res_rust.total_bits);
}
