//! Minimal, dependency-free stand-in for the `anyhow` crate, covering the
//! API subset this repository uses: [`Error`], [`Result`], and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Exists so the workspace builds with zero registry access (this
//! environment is fully offline). The semantics match real `anyhow` for
//! everything the codebase does: `?`-conversion from any
//! `std::error::Error + Send + Sync + 'static`, `Display`/`Debug`
//! rendering of the message, and formatted construction. Error *chains*,
//! downcasting and backtraces are intentionally out of scope — swap the
//! path dependency for crates.io `anyhow = "1"` to get them.

use std::fmt;

/// A string-backed error value.
///
/// Deliberately does **not** implement `std::error::Error` (mirroring
/// real `anyhow::Error`), which is what makes the blanket
/// `From<E: std::error::Error>` impl below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints errors via Debug; show
        // the plain message like real anyhow does.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "boom");
        let e: Error = "17x".parse::<u64>().unwrap_err().into();
        assert!(e.to_string().contains("invalid digit"));
    }

    #[test]
    fn macros_format() {
        let v = 3;
        let e = anyhow!("value {v} bad");
        assert_eq!(e.to_string(), "value 3 bad");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x={x} too big");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x=12 too big");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
    }
}
