//! Stub of the `xla` (PJRT C API) binding surface used by this
//! repository, for environments without the native XLA/PJRT toolchain.
//!
//! Every type the [`fedpaq`] runtime layer touches exists here with the
//! same signatures, so the whole workspace **compiles and links with no
//! native dependency**; attempting to actually *use* PJRT fails at
//! runtime from the single entry point ([`PjRtClient::cpu`]) with a
//! clear message. The pure-rust engine, coordinator, codecs and TCP
//! runtime never reach this crate's error path.
//!
//! To run the real AOT-HLO path, replace the `xla = { path = ... }`
//! dependency with the actual PJRT bindings exposing this same surface.

use std::fmt;
use std::path::Path;

/// Error type surfaced by every stubbed operation.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT backend unavailable (built against the in-tree xla stub; \
         use --engine rust, or link the real xla bindings)"
    )))
}

/// Element types transferable to device buffers.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn scalar(_v: f32) -> Self {
        Literal { _priv: () }
    }

    pub fn vec1(_v: &[f32]) -> Self {
        Literal { _priv: () }
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T: ArrayElement>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (stub: never constructible from text here).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// On-device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle (the single stub entry point — fails on creation).
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}
