//! Micro-benchmarks for the L3 hot paths: quantizer codec throughput,
//! Elias coding, aggregation, node sampling and batch gathering.
//!
//! These isolate the coordinator-side cost per round so EXPERIMENTS.md
//! §Perf can verify L3 stays far below the PJRT execute time.
//! (Harness: `fedpaq::util::bench` — criterion is unavailable offline.)

use fedpaq::coordinator::aggregate::Aggregator;
use fedpaq::coordinator::local::{gather_local_batches, GatherBufs};
use fedpaq::coordinator::sampler::sample_nodes;
use fedpaq::data::{BatchSampler, DatasetKind, FederatedDataset, Partition};
use fedpaq::quant::{CodecSpec, Coding, UpdateCodec};
use fedpaq::util::bench::Group;
use fedpaq::util::rng::Rng;
use std::hint::black_box;

fn quantizer_codec() {
    let mut g = Group::new("quant_codec");
    for &p in &[785usize, 92_027, 251_874] {
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.37).sin()).collect();
        for (label, spec) in [
            ("qsgd_s1", CodecSpec::qsgd(1)),
            ("qsgd_s10", CodecSpec::qsgd(10)),
            ("qsgd_s1_elias", CodecSpec::Qsgd { s: 1, coding: Coding::Elias }),
            ("identity", CodecSpec::Identity),
            ("topk_10pct", CodecSpec::top_k(100)),
        ] {
            let q = spec.build().unwrap();
            let mut rng = Rng::seed_from_u64(1);
            g.bench_throughput(&format!("{label}/p{p}"), Some((p * 4) as u64), || {
                let out = q.apply(black_box(&x), &mut rng).unwrap();
                black_box(out);
            });
        }
    }
    g.finish();
}

fn aggregation() {
    let mut g = Group::new("aggregate");
    let p = 92_027;
    let q = CodecSpec::qsgd(1).build().unwrap();
    let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.13).cos() * 0.01).collect();
    let mut rng = Rng::seed_from_u64(2);
    let encs: Vec<_> = (0..25).map(|_| q.encode(&x, &mut rng)).collect();
    // One long-lived aggregator, reset per round: the decode scratch and
    // sum buffers are allocated once, as on the real hot path.
    let mut agg = Aggregator::new(p);
    g.bench("r25_p92k_qsgd1", || {
        agg.reset();
        for e in &encs {
            agg.push(q.as_ref(), e).unwrap();
        }
        let mut params = vec![0f32; p];
        agg.apply(&mut params).unwrap();
        black_box(params);
    });
    g.finish();
}

fn sampling_and_gather() {
    let mut g = Group::new("coordinator_misc");
    let mut round = 0usize;
    g.bench("sample_nodes_50c25", || {
        round += 1;
        black_box(sample_nodes(50, 25, 7, black_box(round)));
    });
    let data = FederatedDataset::generate(DatasetKind::Cifar10, 1, 10_000);
    let part = Partition::iid(10_000, 50, 200, 1);
    let sampler = BatchSampler::new(1, 10);
    let mut bufs = GatherBufs::default();
    g.bench("gather_tau5_b10_cifar", || {
        let labels =
            gather_local_batches(&data, part.shard(7), &sampler, 7, black_box(3), 5, &mut bufs);
        black_box(labels);
    });
    g.finish();
}

fn main() {
    quantizer_codec();
    aggregation();
    sampling_and_gather();
}
