//! Micro-benchmarks for the L3 hot paths: quantizer codec throughput,
//! Elias coding, aggregation, node sampling and batch gathering.
//!
//! These isolate the coordinator-side cost per round so EXPERIMENTS.md
//! §Perf can verify L3 stays far below the PJRT execute time.
//! (Harness: `fedpaq::util::bench` — criterion is unavailable offline.)

use fedpaq::coordinator::aggregate::{Aggregator, ShardPlan};
use fedpaq::coordinator::local::{gather_local_batches, GatherBufs};
use fedpaq::coordinator::sampler::sample_nodes;
use fedpaq::data::{BatchSampler, DatasetKind, FederatedDataset, Partition};
use fedpaq::quant::{CodecSpec, Coding, Encoded, UpdateCodec};
use fedpaq::util::bench::Group;
use fedpaq::util::rng::Rng;
use std::hint::black_box;

fn quantizer_codec() {
    let mut g = Group::new("quant_codec");
    for &p in &[785usize, 92_027, 251_874] {
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.37).sin()).collect();
        for (label, spec) in [
            ("qsgd_s1", CodecSpec::qsgd(1)),
            ("qsgd_s10", CodecSpec::qsgd(10)),
            ("qsgd_s1_elias", CodecSpec::Qsgd { s: 1, coding: Coding::Elias }),
            ("identity", CodecSpec::Identity),
            ("topk_10pct", CodecSpec::top_k(100)),
        ] {
            let q = spec.build().unwrap();
            let mut rng = Rng::seed_from_u64(1);
            g.bench_throughput(&format!("{label}/p{p}"), Some((p * 4) as u64), || {
                let out = q.apply(black_box(&x), &mut rng).unwrap();
                black_box(out);
            });
        }
    }
    g.finish();
}

/// Per-codec encode/decode throughput over a 2^20-parameter vector — one
/// row per (direction, codec family member), emitted as
/// `BENCH_codecs.json` and gated by CI against the committed floors in
/// `rust/benches/baseline/BENCH_codecs.json` (python/bench_check.py), so
/// a codec that silently falls off a cliff fails the bench job by name.
fn codec_suite() {
    let mut g = Group::new("codecs");
    let p: usize = 1 << 20;
    let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.37).sin() * 0.01).collect();
    for (label, spec) in [
        ("identity", CodecSpec::Identity),
        ("qsgd_s1", CodecSpec::qsgd(1)),
        ("qsgd_s7_elias", CodecSpec::Qsgd { s: 7, coding: Coding::Elias }),
        ("topk_100", CodecSpec::top_k(100)),
        ("randk_100_seeded", CodecSpec::rand_k(100)),
        ("randk_100_elias", CodecSpec::RandK { k_permille: 100, seeded: false }),
        ("adaptive_b4", CodecSpec::adaptive(4)),
        ("ef_topk_100", CodecSpec::error_feedback(CodecSpec::top_k(100))),
        ("ef_qsgd_s1", CodecSpec::error_feedback(CodecSpec::qsgd(1))),
    ] {
        let q = spec.build().unwrap();
        let mut rng = Rng::seed_from_u64(7);
        // Encode throughput. Stateful codecs pay their residual update
        // here too — that cost is part of the codec, so it is gated.
        g.bench_elems(&format!("encode/{label}"), p as u64, || {
            let enc = q.encode_node(0, black_box(&x), &mut rng);
            black_box(enc);
        });
        // Decode throughput against one representative frame, into a
        // reused buffer (the aggregation hot path's shape).
        let enc = q.encode(&x, &mut Rng::seed_from_u64(8));
        let mut out: Vec<f32> = Vec::new();
        g.bench_elems(&format!("decode/{label}"), p as u64, || {
            q.decode_into(black_box(&enc), &mut out).unwrap();
            black_box(&out);
        });
    }
    g.finish();
}

fn aggregation() {
    let mut g = Group::new("aggregate");
    let p = 92_027;
    let q = CodecSpec::qsgd(1).build().unwrap();
    let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.13).cos() * 0.01).collect();
    let mut rng = Rng::seed_from_u64(2);
    let encs: Vec<_> = (0..25).map(|_| q.encode(&x, &mut rng)).collect();
    // One long-lived aggregator, reset per round: the sum buffer is
    // allocated once and uploads stream in fused, as on the real hot
    // path.
    let mut agg = Aggregator::new(p);
    g.bench_elems("r25_p92k_qsgd1", (25 * p) as u64, || {
        agg.reset();
        for e in &encs {
            agg.push(q.as_ref(), e).unwrap();
        }
        let mut params = vec![0f32; p];
        agg.apply(&mut params).unwrap();
        black_box(params);
    });

    // The million-parameter regime sharded aggregation exists for: one
    // commit of r=8 uploads over a 2^20-parameter model, accumulate +
    // apply, across shard counts. `shards1` goes through the identical
    // sequential path as the seed's aggregator; the CI regression gate
    // (python/bench_check.py vs rust/benches/baseline/) watches the
    // elems/s of every row, and the shard spread demonstrates the scaling
    // the ISSUE's acceptance criteria ask for. Results are bit-identical
    // across rows by the aggregate module's determinism contract.
    let p = 1 << 20;
    let r = 8;
    let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.37).sin() * 0.01).collect();
    let mut rng = Rng::seed_from_u64(3);
    let encs: Vec<Encoded> = (0..r).map(|_| q.encode(&x, &mut rng)).collect();
    let batch: Vec<(&Encoded, f64)> = encs.iter().map(|e| (e, 1.0)).collect();
    let mut agg = Aggregator::new(p);
    let mut params = vec![0f32; p];
    for shards in [1usize, 2, 4, 8] {
        let plan = ShardPlan::new(p, shards);
        g.bench_elems(
            &format!("p1m_r8_qsgd1/shards{shards}"),
            (r * p) as u64,
            || {
                agg.reset();
                agg.push_batch(q.as_ref(), black_box(&batch), &plan).unwrap();
                agg.apply_sharded(&mut params, &plan).unwrap();
                black_box(&params);
            },
        );
    }

    // Fused (`UpdateCodec::accumulate_range`) vs scratch (`decode_range`
    // + widening add — the pre-fusion hot loop, kept here as the
    // comparison baseline) at the same r=8 × 2^20 commit shape, across
    // every codec family. The fused/scratch ratio is the ISSUE's
    // measured multiple; both rows are floored in
    // rust/benches/baseline/BENCH_aggregate.json so neither side of the
    // comparison can silently rot.
    for (label, spec) in [
        ("identity", CodecSpec::Identity),
        ("qsgd1", CodecSpec::qsgd(1)),
        ("qsgd_s7_elias", CodecSpec::Qsgd { s: 7, coding: Coding::Elias }),
        ("topk_100", CodecSpec::top_k(100)),
        ("randk_100_seeded", CodecSpec::rand_k(100)),
        ("adaptive_b4", CodecSpec::adaptive(4)),
        ("ef_qsgd1", CodecSpec::error_feedback(CodecSpec::qsgd(1))),
    ] {
        let q = spec.build().unwrap();
        let mut rng = Rng::seed_from_u64(4);
        let encs: Vec<Encoded> = (0..r).map(|_| q.encode(&x, &mut rng)).collect();
        let mut sum = vec![0f64; p];
        g.bench_elems(&format!("p1m_r8_{label}/fused"), (r * p) as u64, || {
            sum.iter_mut().for_each(|s| *s = 0.0);
            for e in &encs {
                q.accumulate_range(black_box(e), 0, p, 1.0, &mut sum).unwrap();
            }
            black_box(&sum);
        });
        let mut scratch: Vec<f32> = Vec::new();
        g.bench_elems(&format!("p1m_r8_{label}/scratch"), (r * p) as u64, || {
            sum.iter_mut().for_each(|s| *s = 0.0);
            for e in &encs {
                q.decode_range(black_box(e), 0, p, &mut scratch).unwrap();
                for (acc, &v) in sum.iter_mut().zip(&scratch) {
                    *acc += v as f64;
                }
            }
            black_box(&sum);
        });
    }
    g.finish();
}

fn sampling_and_gather() {
    let mut g = Group::new("coordinator_misc");
    let mut round = 0usize;
    g.bench("sample_nodes_50c25", || {
        round += 1;
        black_box(sample_nodes(50, 25, 7, black_box(round)));
    });
    let data = FederatedDataset::generate(DatasetKind::Cifar10, 1, 10_000);
    let part = Partition::iid(10_000, 50, 200);
    let sampler = BatchSampler::new(1, 10);
    let mut bufs = GatherBufs::default();
    g.bench("gather_tau5_b10_cifar", || {
        let labels =
            gather_local_batches(&data, part.shard(7), &sampler, 7, black_box(3), 5, &mut bufs);
        black_box(labels);
    });
    g.finish();
}

/// Simulator throughput at cohort scale: full `AsyncSim` commits
/// (dispatch wave → event-queue arrivals → planner decision) at 10^4 and
/// 10^5 clients, emitted as `BENCH_sim.json` and gated by CI against the
/// committed floors in `rust/benches/baseline/BENCH_sim.json`. Per-commit
/// cost must be O(active) — the two rows differ by 10× in cohort size but
/// share the same active set (r=64, b=32), so a regression that
/// reintroduces O(n_nodes) work shows up as the 10^5 row (and only it)
/// falling off a cliff.
fn sim_throughput() {
    use fedpaq::config::{EngineKind, ExperimentConfig};
    use fedpaq::coordinator::{AsyncSim, ModelFrame, RoundCtx, Transport};
    use fedpaq::data::PartitionKind;
    use fedpaq::model::{Engine, ModelKind, RustEngine};
    use fedpaq::opt::LrSchedule;

    let mut g = Group::new("sim");
    for &(label, n_nodes) in &[("commit_n1e4_r64_b32", 10_000usize),
                               ("commit_n1e5_r64_b32", 100_000usize)] {
        let cfg = ExperimentConfig {
            name: format!("bench-{label}"),
            model: "logreg".into(),
            dataset: DatasetKind::Mnist08,
            n_nodes,
            per_node: 32,
            r: 64,
            tau: 1,
            t_total: 1_000_000,
            codec: CodecSpec::qsgd(2),
            down_codec: None,
            lr: LrSchedule::Const { eta: 0.05 },
            ratio: 100.0,
            seed: 17,
            eval_every: 1,
            engine: EngineKind::Rust,
            partition: PartitionKind::Iid,
            async_rounds: true,
            buffer_size: 32,
            max_staleness: 16,
            staleness_rule: Default::default(),
            agg_shards: 1,
            straggler: Default::default(),
            // O(r + dataset) resident state: shards wrap a 4096-sample
            // dataset however large the cohort is.
            dataset_cap: 4096,
        };
        let codec = cfg.codec.build().unwrap();
        let mut eng =
            RustEngine::new(ModelKind::LogReg { d: 784, l2: 0.05 }, 8, 256).unwrap();
        let params = eng.init_params().unwrap();
        let mut t = AsyncSim::new();
        t.setup(&cfg, &mut eng).unwrap();
        let mut round = 0usize;
        let lrs = vec![0.05f32; cfg.tau];
        // One commit per iteration ≈ b pops + b dispatches in steady
        // state; rounds stay sequential across bench iterations (the
        // planner requires it).
        let events_per_commit = 2 * cfg.buffer_size as u64;
        g.bench_elems(label, events_per_commit, || {
            let nodes = sample_nodes(cfg.n_nodes, cfg.r, cfg.seed, round);
            let frame = ModelFrame::raw(round, params.clone());
            let ctx =
                RoundCtx { round, nodes: &nodes, frame: &frame, lrs: &lrs };
            let out = t.round(&ctx, codec.as_ref(), &mut eng).unwrap();
            black_box(out);
            round += 1;
        });
        t.shutdown().unwrap();
    }
    g.finish();
}

/// Edge-leader partial aggregation: sum an 8-upload cohort over a
/// 2^20-parameter model and re-encode the result through the same codec
/// ([`fedpaq::net::partial_reencode`] — the summed-mode tree hot path),
/// per codec family. Emitted as `BENCH_tree.json` and gated by CI
/// against the committed floors in
/// `rust/benches/baseline/BENCH_tree.json`: the edge re-encode sits on
/// every commit's critical path in a summed tree, so a family that
/// silently slows down fails the bench job by name.
fn tree_partial() {
    let mut g = Group::new("tree");
    let p: usize = 1 << 20;
    let cohort = 8usize;
    let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.37).sin() * 0.01).collect();
    for (label, spec) in [
        ("identity", CodecSpec::Identity),
        ("qsgd1", CodecSpec::qsgd(1)),
        ("qsgd_s7_elias", CodecSpec::Qsgd { s: 7, coding: Coding::Elias }),
        ("topk_100", CodecSpec::top_k(100)),
        ("randk_100_seeded", CodecSpec::rand_k(100)),
        ("adaptive_b4", CodecSpec::adaptive(4)),
    ] {
        let q = spec.build().unwrap();
        let mut rng = Rng::seed_from_u64(5);
        let encs: Vec<Encoded> = (0..cohort).map(|_| q.encode(&x, &mut rng)).collect();
        let mut re_rng = Rng::seed_from_u64(6);
        g.bench_elems(
            &format!("partial_reencode_p1m_c8/{label}"),
            (cohort * p) as u64,
            || {
                let out =
                    fedpaq::net::partial_reencode(q.as_ref(), black_box(&encs), p, &mut re_rng)
                        .unwrap();
                black_box(out);
            },
        );
    }
    g.finish();
}

fn main() {
    quantizer_codec();
    codec_suite();
    aggregation();
    sampling_and_gather();
    sim_throughput();
    tree_partial();
}
