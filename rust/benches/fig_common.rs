//! Shared helper for the per-figure benches.
//!
//! Each paper figure has a bench that measures the *end-to-end round
//! pipeline* of its workload (sample → broadcast → τ local PJRT steps ×
//! r nodes → quantize → aggregate → clock) at a reduced T; one sample =
//! one full (shortened) training run including world setup. The complete
//! full-length figure series are regenerated with `fedpaq figure <id>`
//! (or `make figures`); EXPERIMENTS.md records those curves.

use fedpaq::config::EngineKind;
use fedpaq::figures::{figure, Runner};
use fedpaq::util::bench::Group;
use std::time::Duration;

/// Benchmark every curve of figure `fig_id`, truncated to `t_total` SGD
/// iterations per run. Skips (with a message) when artifacts are missing
/// so `cargo bench` degrades gracefully before `make artifacts`.
pub fn bench_figure(group: &str, fig_id: &str, t_total: usize) {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("[{group}] artifacts missing — run `make artifacts`; skipping");
        return;
    }
    let spec = figure(fig_id).unwrap_or_else(|| panic!("unknown figure {fig_id}"));
    let mut runner = Runner::new(EngineKind::Pjrt, "artifacts");
    runner.t_override = Some(t_total);
    let mut g = Group::new(group);
    g.sample_size = 5;
    g.target_time = Duration::from_secs(2);
    for cfg in &spec.configs {
        g.bench(&cfg.name, || {
            runner
                .run_config(cfg.clone(), fedpaq::ops::RunControl::default())
                .expect("run failed");
        });
    }
    g.finish();
}
