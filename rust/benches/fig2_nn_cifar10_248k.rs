//! End-to-end bench for the workload of Fig 2 (mlp248k/CIFAR-10): FedPAQ vs FedAvg vs
//! QSGD round pipeline at reduced T. Full series: `fedpaq figure fig2*`.

#[path = "fig_common.rs"]
mod fig_common;

fn main() {
    fig_common::bench_figure("fig2_nn_cifar10_248k", "fig2d", 2);
}
