//! End-to-end bench for the workload of Fig 4 (Fashion-MNIST): FedPAQ vs FedAvg vs
//! QSGD round pipeline at reduced T. Full series: `fedpaq figure fig4*`.

#[path = "fig_common.rs"]
mod fig_common;

fn main() {
    fig_common::bench_figure("fig4_nn_fashion", "fig4d", 4);
}
