//! End-to-end bench for the workload of Fig 1 bottom (mlp92k/CIFAR-10): FedPAQ vs FedAvg vs
//! QSGD round pipeline at reduced T. Full series: `fedpaq figure fig1h*`.

#[path = "fig_common.rs"]
mod fig_common;

fn main() {
    fig_common::bench_figure("fig1_nn_cifar10", "fig1h", 4);
}
