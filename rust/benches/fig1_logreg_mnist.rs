//! End-to-end bench for the workload of Fig 1 top (logreg/MNIST): FedPAQ vs FedAvg vs
//! QSGD round pipeline at reduced T. Full series: `fedpaq figure fig1*`.

#[path = "fig_common.rs"]
mod fig_common;

fn main() {
    fig_common::bench_figure("fig1_logreg_mnist", "fig1d", 10);
}
