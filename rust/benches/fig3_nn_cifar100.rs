//! End-to-end bench for the workload of Fig 3 (CIFAR-100): FedPAQ vs FedAvg vs
//! QSGD round pipeline at reduced T. Full series: `fedpaq figure fig3*`.

#[path = "fig_common.rs"]
mod fig_common;

fn main() {
    fig_common::bench_figure("fig3_nn_cifar100", "fig3d", 2);
}
